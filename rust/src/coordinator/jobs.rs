//! Scoping-job queue: the leader/worker service front of the coordinator.
//!
//! Customers (or the CLI) submit [`ScopeJob`]s; a leader thread drains the
//! queue in FIFO order and runs each sweep (each sweep fans its trials out
//! over the shared thread pool). Results are retrievable by job id, so a
//! long-running service can scope many customer use cases concurrently
//! with bounded resources — the "autonomous" part of the paper's title.

use super::sweep::{run_sweep_cached, Backend, CellStore, SweepResult, SweepSpec};
use std::collections::HashMap;
use std::sync::mpsc;
use std::sync::{Arc, Condvar, Mutex};

/// Job identifier.
pub type JobId = u64;

/// Completed (done/failed) jobs retained for status queries. Oldest
/// completed results are evicted beyond this, so a long-running service
/// does not grow without bound; in-flight jobs are never evicted.
pub const COMPLETED_RETAIN: usize = 256;

/// Job status as observed by clients.
#[derive(Clone, Debug)]
pub enum JobStatus {
    /// Accepted, waiting for the leader thread.
    Queued,
    /// Sweep in progress.
    Running,
    /// Sweep finished; the result is shared until evicted.
    Done(Arc<SweepResult>),
    /// Sweep failed with this error message.
    Failed(String),
}

/// One submitted scoping request.
#[derive(Clone, Debug)]
pub struct ScopeJob {
    /// Identifier handed back to the submitter.
    pub id: JobId,
    /// The sweep to run (exhaustive or adaptive — see
    /// [`SweepSpec::adaptive`]).
    pub spec: SweepSpec,
}

struct Shared {
    statuses: Mutex<HashMap<JobId, JobStatus>>,
    done: Condvar,
}

/// The scoping service (leader thread + job registry).
///
/// The sender sits behind a `Mutex` so the service is `Sync` and can be
/// shared across the HTTP connection-handler threads.
pub struct ScopingService {
    tx: Mutex<Option<mpsc::Sender<ScopeJob>>>,
    shared: Arc<Shared>,
    next_id: Mutex<JobId>,
    leader: Option<std::thread::JoinHandle<()>>,
    /// Max queued+running jobs before submits are rejected (backpressure).
    queue_cap: usize,
}

impl ScopingService {
    /// Start a service over the given execution backend. `queue_cap`
    /// bounds the number of queued jobs (backpressure: submits fail fast
    /// beyond it rather than accumulating unbounded work).
    pub fn start(backend: Backend, queue_cap: usize) -> ScopingService {
        Self::start_with_cache(backend, queue_cap, None)
    }

    /// [`ScopingService::start`] with a shared cell store: cells measured
    /// by any job are reused by every later job with an identical cell
    /// context (see [`crate::service::cache`] for the standard store).
    pub fn start_with_cache(
        backend: Backend,
        queue_cap: usize,
        cache: Option<Arc<dyn CellStore>>,
    ) -> ScopingService {
        let (tx, rx) = mpsc::channel::<ScopeJob>();
        let shared = Arc::new(Shared {
            statuses: Mutex::new(HashMap::new()),
            done: Condvar::new(),
        });
        let shared2 = Arc::clone(&shared);
        let leader = std::thread::Builder::new()
            .name("scoping-leader".into())
            .spawn(move || {
                while let Ok(job) = rx.recv() {
                    {
                        let mut st = shared2.statuses.lock().unwrap();
                        st.insert(job.id, JobStatus::Running);
                    }
                    let result =
                        run_sweep_cached(&job.spec, backend.clone(), cache.as_deref());
                    let status = match result {
                        Ok(r) => JobStatus::Done(Arc::new(r)),
                        Err(e) => JobStatus::Failed(e.to_string()),
                    };
                    let mut st = shared2.statuses.lock().unwrap();
                    st.insert(job.id, status);
                    // Evict the oldest completed entries beyond the
                    // retention bound (ids are monotonic → oldest = min).
                    let mut completed: Vec<JobId> = st
                        .iter()
                        .filter(|(_, s)| {
                            matches!(s, JobStatus::Done(_) | JobStatus::Failed(_))
                        })
                        .map(|(&id, _)| id)
                        .collect();
                    if completed.len() > COMPLETED_RETAIN {
                        completed.sort_unstable();
                        for id in &completed[..completed.len() - COMPLETED_RETAIN] {
                            st.remove(id);
                        }
                    }
                    shared2.done.notify_all();
                }
            })
            .expect("spawn leader");
        ScopingService {
            tx: Mutex::new(Some(tx)),
            shared,
            next_id: Mutex::new(1),
            leader: Some(leader),
            queue_cap: queue_cap.max(1),
        }
    }

    /// Submit a sweep; returns its job id, or an error when the queue is
    /// saturated (backpressure).
    pub fn submit(&self, spec: SweepSpec) -> anyhow::Result<JobId> {
        // Count + insert under one statuses lock, so concurrent submitters
        // cannot jointly overshoot the cap (check-then-act would race).
        let id = {
            let mut st = self.shared.statuses.lock().unwrap();
            let queued = st
                .values()
                .filter(|s| matches!(s, JobStatus::Queued | JobStatus::Running))
                .count();
            let cap = self.queue_cap;
            anyhow::ensure!(
                queued < cap,
                "scoping queue saturated ({queued}/{cap}); retry later"
            );
            let id = {
                let mut n = self.next_id.lock().unwrap();
                let id = *n;
                *n += 1;
                id
            };
            st.insert(id, JobStatus::Queued);
            id
        };
        let sent = self
            .tx
            .lock()
            .unwrap()
            .as_ref()
            .expect("service stopped")
            .send(ScopeJob { id, spec });
        if sent.is_err() {
            // Roll the reservation back, or the dead leader's ghost jobs
            // would pin in_flight() at the cap forever.
            self.shared.statuses.lock().unwrap().remove(&id);
            anyhow::bail!("leader thread gone");
        }
        Ok(id)
    }

    /// Number of jobs currently queued or running (the backpressure gauge
    /// reported by the service's `/healthz`).
    pub fn in_flight(&self) -> usize {
        self.shared
            .statuses
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, JobStatus::Queued | JobStatus::Running))
            .count()
    }

    /// Configured backpressure bound.
    pub fn queue_cap(&self) -> usize {
        self.queue_cap
    }

    /// Non-blocking status check.
    pub fn status(&self, id: JobId) -> Option<JobStatus> {
        self.shared.statuses.lock().unwrap().get(&id).cloned()
    }

    /// Block until a job completes (or fails).
    pub fn wait(&self, id: JobId) -> anyhow::Result<Arc<SweepResult>> {
        let mut st = self.shared.statuses.lock().unwrap();
        loop {
            match st.get(&id) {
                None => anyhow::bail!("unknown job {id}"),
                Some(JobStatus::Done(r)) => return Ok(Arc::clone(r)),
                Some(JobStatus::Failed(e)) => anyhow::bail!("job {id} failed: {e}"),
                Some(_) => {
                    st = self.shared.done.wait(st).unwrap();
                }
            }
        }
    }

    /// Graceful shutdown: stop accepting, finish queued work.
    pub fn shutdown(mut self) {
        self.tx.lock().unwrap().take();
        if let Some(l) = self.leader.take() {
            let _ = l.join();
        }
    }
}

impl Drop for ScopingService {
    fn drop(&mut self) {
        self.tx.lock().unwrap().take();
        if let Some(l) = self.leader.take() {
            let _ = l.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_spec() -> SweepSpec {
        SweepSpec {
            signals: vec![4],
            memvecs: vec![8],
            obs: vec![32],
            trials: 1,
            seed: 2,
            model: "mset2".into(),
            workers: 1,
            ..SweepSpec::default()
        }
    }

    #[test]
    fn submit_and_wait_roundtrip() {
        let svc = ScopingService::start(Backend::Native, 8);
        let id = svc.submit(tiny_spec()).unwrap();
        let res = svc.wait(id).unwrap();
        assert_eq!(res.cells.len(), 1);
        svc.shutdown();
    }

    #[test]
    fn jobs_processed_in_order_with_distinct_ids() {
        let svc = ScopingService::start(Backend::Native, 8);
        let a = svc.submit(tiny_spec()).unwrap();
        let b = svc.submit(tiny_spec()).unwrap();
        assert_ne!(a, b);
        svc.wait(a).unwrap();
        svc.wait(b).unwrap();
        svc.shutdown();
    }

    #[test]
    fn unknown_job_errors() {
        let svc = ScopingService::start(Backend::Native, 8);
        assert!(svc.wait(999).is_err());
        assert!(svc.status(999).is_none());
    }

    #[test]
    fn backpressure_rejects_when_saturated() {
        let svc = ScopingService::start(Backend::Native, 1);
        // A job heavy enough to still be in flight when the next submit
        // arrives microseconds later.
        let slow = SweepSpec {
            obs: vec![4096],
            trials: 3,
            ..tiny_spec()
        };
        let id = svc.submit(slow.clone()).unwrap();
        let err = svc.submit(slow).unwrap_err().to_string();
        assert!(err.contains("saturated"), "{err}");
        svc.wait(id).unwrap();
        // capacity frees once the job completes
        let id2 = svc.submit(tiny_spec()).unwrap();
        svc.wait(id2).unwrap();
        assert_eq!(svc.in_flight(), 0);
        assert_eq!(svc.queue_cap(), 1);
        svc.shutdown();
    }

    #[test]
    fn cached_service_skips_remeasurement() {
        let cache = Arc::new(crate::service::cache::SweepCache::in_memory());
        let svc = ScopingService::start_with_cache(
            Backend::Native,
            8,
            Some(Arc::clone(&cache) as Arc<dyn CellStore>),
        );
        let id = svc.submit(tiny_spec()).unwrap();
        svc.wait(id).unwrap();
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        let id2 = svc.submit(tiny_spec()).unwrap();
        svc.wait(id2).unwrap();
        assert_eq!(cache.hits(), 1, "identical request must be cache-served");
        svc.shutdown();
    }

    #[test]
    fn completed_jobs_are_evicted_beyond_retention() {
        let svc = ScopingService::start(Backend::Native, 8);
        let total = COMPLETED_RETAIN + 2;
        let mut last = 0;
        for _ in 0..total {
            last = svc.submit(tiny_spec()).unwrap();
            svc.wait(last).unwrap();
        }
        assert!(svc.status(1).is_none(), "oldest job must be evicted");
        assert!(svc.status(last).is_some(), "newest job must be retained");
        svc.shutdown();
    }

    #[test]
    fn failed_job_reports_error() {
        let svc = ScopingService::start(Backend::Native, 8);
        let bad = SweepSpec {
            model: "no-such-model".into(),
            ..tiny_spec()
        };
        let id = svc.submit(bad).unwrap();
        let err = svc.wait(id).unwrap_err().to_string();
        assert!(err.contains("failed"), "{err}");
        svc.shutdown();
    }
}
