//! Work-stealing parallel map + persistent worker pool.
//!
//! `tokio`/`rayon` are unavailable offline; the sweep engine is compute-bound
//! fan-out, so a scoped thread pool with an atomic work index covers the need.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};

/// Run `f(i, &items[i])` over all items on `workers` threads, returning the
/// results in input order. `f` must be `Sync` (it is shared, not cloned).
pub fn parallel_map<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let workers = workers.clamp(1, items.len().max(1));
    if workers == 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<R>> = Vec::with_capacity(items.len());
    out.resize_with(items.len(), || None);
    let out_slots: Vec<Mutex<&mut Option<R>>> = out.iter_mut().map(Mutex::new).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                **out_slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    drop(out_slots);
    out.into_iter().map(|o| o.expect("worker missed slot")).collect()
}

/// Number of usable worker threads on this machine.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// A persistent FIFO job pool for the coordinator's leader/worker topology:
/// jobs are boxed closures; results arrive on a channel as they complete.
pub struct JobPool {
    tx: Option<mpsc::Sender<Job>>,
    handles: Vec<std::thread::JoinHandle<()>>,
    /// Number of worker threads.
    pub workers: usize,
}

type Job = Box<dyn FnOnce() + Send + 'static>;

impl JobPool {
    /// Spawn a pool with `workers` threads (min 1).
    pub fn new(workers: usize) -> JobPool {
        let workers = workers.max(1);
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        let handles = (0..workers)
            .map(|_| {
                let rx = Arc::clone(&rx);
                std::thread::spawn(move || loop {
                    let job = {
                        let guard = rx.lock().unwrap();
                        guard.recv()
                    };
                    match job {
                        Ok(job) => job(),
                        Err(_) => break, // pool dropped
                    }
                })
            })
            .collect();
        JobPool {
            tx: Some(tx),
            handles,
            workers,
        }
    }

    /// Submit a job; its result is delivered on `result_tx`.
    pub fn submit<R, F>(&self, f: F, result_tx: mpsc::Sender<R>)
    where
        R: Send + 'static,
        F: FnOnce() -> R + Send + 'static,
    {
        let job: Job = Box::new(move || {
            let r = f();
            // Receiver may have hung up if the submitter gave up; ignore.
            let _ = result_tx.send(r);
        });
        self.tx
            .as_ref()
            .expect("pool already shut down")
            .send(job)
            .expect("worker threads gone");
    }

    /// Wait for all workers to drain and exit.
    pub fn shutdown(mut self) {
        self.tx.take(); // close channel
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for JobPool {
    fn drop(&mut self) {
        self.tx.take();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(8, &items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_worker() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(1, &items, |_, &x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<u8> = vec![];
        assert!(parallel_map(4, &items, |_, &x| x).is_empty());
    }

    #[test]
    fn parallel_map_actually_parallel() {
        // All workers must be in-flight at once for this to finish quickly.
        use std::sync::atomic::AtomicUsize;
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let items: Vec<usize> = (0..8).collect();
        parallel_map(8, &items, |_, _| {
            let n = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(n, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2, "no overlap observed");
    }

    #[test]
    fn job_pool_roundtrip() {
        let pool = JobPool::new(4);
        let (tx, rx) = mpsc::channel();
        for i in 0..100usize {
            pool.submit(move || i * i, tx.clone());
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..100).map(|i| i * i).collect::<Vec<_>>());
        pool.shutdown();
    }
}
