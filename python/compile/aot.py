"""AOT lowering: JAX graphs → HLO *text* artifacts + manifest.

Python runs only here, at build time (``make artifacts``). The Rust
coordinator loads the emitted ``*.hlo.txt`` through the PJRT CPU client and
never imports Python on the request path.

Interchange is HLO **text**, not serialized HloModuleProto: jax ≥ 0.5 emits
protos with 64-bit instruction ids which xla_extension 0.5.1 (the version
the published ``xla`` crate binds) rejects; the text parser reassigns ids
and round-trips cleanly (see /opt/xla-example/README.md).

Buckets: XLA executables are shape-specialised, so we emit one artifact per
(graph, n, m) bucket; the Rust router zero-pads workloads up to the nearest
bucket (masking contract in ``model.py``). ``--profile dev`` emits a small
grid for fast tests; ``--profile full`` emits the grid the paper figures
need (scaled per DESIGN.md §5).
"""

import argparse
import json
import os

import jax

# The training graph computes its Newton–Schulz inverse in f64 (see
# model.mset2_train); x64 must be enabled before any tracing happens.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import ref

#: (signals, memvecs) bucket grids per profile. The MSET training
#: constraint m ≥ 2n (paper Fig. 6) filters invalid pairs.
PROFILES = {
    "dev": {
        "signals": [8, 16],
        "memvecs": [32, 64],
        "chunk": 32,
    },
    "full": {
        "signals": [8, 16, 32, 64, 128],
        "memvecs": [32, 64, 128, 256, 512],
        "chunk": 64,
    },
}

GRAPHS = ["mset2_train", "mset2_surveil", "aakr_surveil"]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_graph(graph, n, m, chunk):
    """Lower one bucketed graph; returns (hlo_text, inputs, outputs)."""
    d = spec((m, n))
    g = spec((m, m))
    mask = spec((m,))
    bw = spec((1,))
    x = spec((chunk, n))
    if graph == "mset2_train":
        lowered = jax.jit(model.mset2_train).lower(d, mask, bw)
        inputs = [("d", [m, n]), ("mask", [m]), ("bw", [1])]
        outputs = [("g", [m, m])]
    elif graph == "mset2_surveil":
        lowered = jax.jit(model.mset2_surveil).lower(d, g, mask, bw, x)
        inputs = [
            ("d", [m, n]),
            ("g", [m, m]),
            ("mask", [m]),
            ("bw", [1]),
            ("x", [chunk, n]),
        ]
        outputs = [("xhat", [chunk, n]), ("resid", [chunk, n])]
    elif graph == "aakr_surveil":
        lowered = jax.jit(model.aakr_surveil).lower(d, mask, bw, x)
        inputs = [("d", [m, n]), ("mask", [m]), ("bw", [1]), ("x", [chunk, n])]
        outputs = [("xhat", [chunk, n]), ("resid", [chunk, n])]
    else:
        raise ValueError(graph)
    return to_hlo_text(lowered), inputs, outputs


def emit(out_dir, profile):
    cfg = PROFILES[profile]
    chunk = cfg["chunk"]
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []
    for n in cfg["signals"]:
        for m in cfg["memvecs"]:
            if m < 2 * n:
                continue  # paper's training constraint → surface gap
            for graph in GRAPHS:
                name = f"{graph}_n{n}_m{m}"
                fname = f"{name}.hlo.txt"
                hlo, inputs, outputs = lower_graph(graph, n, m, chunk)
                with open(os.path.join(out_dir, fname), "w") as f:
                    f.write(hlo)
                artifacts.append(
                    {
                        "id": name,
                        "graph": graph,
                        "n": n,
                        "m": m,
                        "chunk": chunk,
                        "file": fname,
                        "inputs": [
                            {"name": nm, "shape": shp} for nm, shp in inputs
                        ],
                        "outputs": [
                            {"name": nm, "shape": shp} for nm, shp in outputs
                        ],
                    }
                )
                print(f"  lowered {name} ({len(hlo)} chars)")
    manifest = {
        "version": 1,
        "profile": profile,
        "gamma": ref.GAMMA,
        "ridge_rel": ref.RIDGE_REL,
        "ns_iters": ref.NS_ITERS,
        "chunk": chunk,
        "signals": cfg["signals"],
        "memvecs": cfg["memvecs"],
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(artifacts)} artifacts + manifest.json to {out_dir}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--profile", choices=sorted(PROFILES), default="dev")
    args = ap.parse_args()
    emit(args.out_dir, args.profile)


if __name__ == "__main__":
    main()
