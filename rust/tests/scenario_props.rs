//! Property tests over fleet-scenario-engine invariants, using the
//! in-repo property harness (`util::prop`): conservation laws the ISSUE
//! demands — cost monotone in demand, a zero-demand fleet costs exactly
//! the idle floor, identical seeds replay bit-identically — plus the
//! degenerate single-tenant equivalence with `shapes::elastic`.

use containerstress::scenario::spec::{
    ArrivalSpec, DemandKind, DemandSpec, PolicySpec, ScenarioSpec,
};
use containerstress::scenario::{run_scenario, ScenarioOutcome};
use containerstress::shapes::elastic::{compare, ElasticPolicy, GrowthTrace};
use containerstress::shapes::{capacity_core_eq, cpu_ladder};
use containerstress::util::prop::{forall, forall_res};
use containerstress::util::rng::Rng;

/// Small random scenario (kept tiny: every case really replays).
fn gen_scenario(rng: &mut Rng) -> ScenarioSpec {
    let kinds = [
        DemandKind::Constant,
        DemandKind::Steps { every: 5 + rng.range_usize(0, 10) },
        DemandKind::Diurnal {
            amplitude: 0.2 + 0.6 * rng.f64(),
            period: 3 + rng.range_usize(0, 10),
        },
        DemandKind::Flash {
            spike: 2.0 + 3.0 * rng.f64(),
            every: 8 + rng.range_usize(0, 8),
            width: 1 + rng.range_usize(0, 3),
        },
    ];
    ScenarioSpec {
        name: "prop".into(),
        seed: rng.next_u64(),
        epochs: 10 + rng.range_usize(0, 30),
        hours_per_epoch: 24.0,
        arrivals: ArrivalSpec {
            initial: 1 + rng.range_usize(0, 4),
            rate_per_epoch: rng.f64(),
            max_tenants: 6 + rng.range_usize(0, 6),
        },
        demand: DemandSpec {
            base: 0.2 + rng.f64(),
            growth_per_epoch: 1.0 + 0.03 * rng.f64(),
            jitter: 0.3 * rng.f64(),
            kind: kinds[rng.range_usize(0, kinds.len())],
        },
        workload: None,
        ..ScenarioSpec::default()
    }
}

fn bit_eq(a: &ScenarioOutcome, b: &ScenarioOutcome) -> Result<(), String> {
    if a.tenants != b.tenants {
        return Err(format!("tenant counts differ: {} vs {}", a.tenants, b.tenants));
    }
    for (pa, pb) in a.policies.iter().zip(&b.policies) {
        if pa.total_usd.to_bits() != pb.total_usd.to_bits() {
            return Err(format!(
                "policy '{}': totals not bit-identical ({} vs {})",
                pa.label, pa.total_usd, pb.total_usd
            ));
        }
        if pa.violation_epochs != pb.violation_epochs || pa.migrations != pb.migrations {
            return Err(format!("policy '{}': counters differ", pa.label));
        }
        let usd_eq = pa
            .usd_per_epoch
            .iter()
            .zip(&pb.usd_per_epoch)
            .all(|(x, y)| x.to_bits() == y.to_bits());
        if !usd_eq || pa.violations_per_epoch != pb.violations_per_epoch {
            return Err(format!("policy '{}': per-epoch series differ", pa.label));
        }
    }
    Ok(())
}

#[test]
fn prop_identical_seed_replays_bit_identically() {
    forall_res(
        "same spec ⇒ bit-identical policy traces (any executor interleaving)",
        8,
        gen_scenario,
        |spec| {
            let a = run_scenario(spec, None, None).map_err(|e| e.to_string())?;
            let b = run_scenario(spec, None, None).map_err(|e| e.to_string())?;
            bit_eq(&a, &b)
        },
    );
}

#[test]
fn prop_zero_demand_costs_exactly_the_idle_floor() {
    forall_res(
        "zero-demand fleet: smallest shape × lived epochs, no violations",
        8,
        |rng| {
            let mut s = gen_scenario(rng);
            s.demand.base = 0.0;
            s
        },
        |spec| {
            let out = run_scenario(spec, None, None).map_err(|e| e.to_string())?;
            // idle floor: every tenant sits on the cheapest ladder shape
            // for exactly the epochs it lives
            let smallest = &cpu_ladder()[0];
            let per_epoch = smallest.usd_per_hour * spec.hours_per_epoch;
            for p in &out.policies {
                if p.violation_epochs != 0 {
                    return Err(format!("policy '{}' violated at zero demand", p.label));
                }
                if p.migrations != 0 {
                    return Err(format!("policy '{}' migrated at zero demand", p.label));
                }
                // tenant-epochs actually lived = total / per_epoch
                let tenant_epochs = (p.total_usd / per_epoch).round();
                let rel = (p.total_usd - tenant_epochs * per_epoch).abs()
                    / p.total_usd.max(1e-9);
                if rel > 1e-9 {
                    return Err(format!(
                        "policy '{}': {} is not a multiple of the idle floor {}",
                        p.label, p.total_usd, per_epoch
                    ));
                }
                if tenant_epochs < out.tenants as f64
                    || tenant_epochs > (out.tenants * spec.epochs) as f64
                {
                    return Err(format!(
                        "policy '{}': {tenant_epochs} tenant-epochs outside fleet bounds",
                        p.label
                    ));
                }
            }
            // all policies agree exactly at the idle floor
            let t0 = out.policies[0].total_usd;
            if !out
                .policies
                .iter()
                .all(|p| (p.total_usd - t0).abs() < 1e-9 * t0.max(1.0))
            {
                return Err("policies disagree on the idle floor".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_prescoped_cost_monotone_in_demand() {
    forall_res(
        "scaling every tenant's demand up cannot reduce pre-scoped cost",
        8,
        |rng| {
            let mut s = gen_scenario(rng);
            s.policies = vec![PolicySpec::PreScoped { headroom: 0.8 }];
            let k = 1.0 + 3.0 * rng.f64();
            (s, k)
        },
        |(spec, k)| {
            let base = run_scenario(spec, None, None).map_err(|e| e.to_string())?;
            let mut scaled = spec.clone();
            scaled.demand.base = spec.demand.base * k;
            let up = run_scenario(&scaled, None, None).map_err(|e| e.to_string())?;
            let (a, b) = (base.policies[0].total_usd, up.policies[0].total_usd);
            if b < a {
                return Err(format!("×{k:.2} demand made the fleet cheaper: {a} → {b}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_degenerate_single_tenant_matches_elastic_bitwise() {
    forall_res(
        "1-tenant constant-growth scenario == shapes::elastic::compare",
        10,
        |rng| {
            let d0 = 0.2 + rng.f64();
            let growth = 1.0 + 0.04 * rng.f64();
            let epochs = 20 + rng.range_usize(0, 120);
            (d0, growth, epochs)
        },
        |&(d0, growth, epochs)| {
            let spec = ScenarioSpec {
                name: "degenerate".into(),
                seed: 1,
                epochs,
                hours_per_epoch: 24.0,
                arrivals: ArrivalSpec {
                    initial: 1,
                    rate_per_epoch: 0.0,
                    max_tenants: 1,
                },
                demand: DemandSpec {
                    base: d0,
                    growth_per_epoch: growth,
                    jitter: 0.0,
                    kind: DemandKind::Constant,
                },
                workload: None,
                policies: vec![
                    PolicySpec::PreScoped { headroom: 0.8 },
                    PolicySpec::Reactive(ElasticPolicy::default()),
                ],
            };
            let out = run_scenario(&spec, None, None).map_err(|e| e.to_string())?;
            let trace = GrowthTrace::exponential(d0, growth, epochs, 24.0)
                .map_err(|e| e.to_string())?;
            let (fixed, elastic) = compare(&trace, &ElasticPolicy::default());
            for (engine, reference, name) in [
                (&out.policies[0], &fixed, "prescoped"),
                (&out.policies[1], &elastic, "reactive"),
            ] {
                if engine.total_usd.to_bits() != reference.total_usd.to_bits() {
                    return Err(format!(
                        "{name}: engine {} != elastic {} (not bit-identical)",
                        engine.total_usd, reference.total_usd
                    ));
                }
                if engine.violation_epochs != reference.violation_epochs
                    || engine.migrations != reference.migrations
                {
                    return Err(format!("{name}: violation/migration counters differ"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_fleet_totals_reconcile_with_series() {
    forall(
        "fleet per-epoch series sum to the policy total",
        8,
        gen_scenario,
        |spec| {
            let out = run_scenario(spec, None, None).unwrap();
            out.policies.iter().all(|p| {
                let sum: f64 = p.usd_per_epoch.iter().sum();
                p.usd_per_epoch.len() == spec.epochs
                    && (sum - p.total_usd).abs() < 1e-6 * p.total_usd.max(1.0)
            })
        },
    );
}

#[test]
fn prop_capacity_ladder_supports_engine_invariants() {
    // The engine's correctness leans on a sorted ladder with positive
    // capacities; pin that here so a future catalog edit cannot silently
    // break the policies.
    let ladder = cpu_ladder();
    assert!(ladder.len() >= 2);
    for w in ladder.windows(2) {
        assert!(capacity_core_eq(&w[0]) < capacity_core_eq(&w[1]));
    }
    assert!(ladder.iter().all(|s| capacity_core_eq(s) > 0.0));
}
