//! Artifact manifest — the contract between `python/compile/aot.py` (which
//! writes `artifacts/manifest.json` at build time) and the Rust runtime
//! (which loads it at startup and never touches Python again).

use crate::util::json::Json;
use std::path::{Path, PathBuf};

/// Shape of one graph input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    /// Input/output name from the lowered graph.
    pub name: String,
    /// Dimensions of the buffer.
    pub shape: Vec<usize>,
}

/// One AOT-lowered executable.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Unique artifact id (file stem).
    pub id: String,
    /// Graph family: `mset2_train` | `mset2_surveil` | `aakr_surveil`.
    pub graph: String,
    /// Bucket signal count.
    pub n: usize,
    /// Bucket memory-vector count.
    pub m: usize,
    /// Observation-chunk rows for surveillance graphs.
    pub chunk: usize,
    /// HLO text file, relative to the artifact directory.
    pub file: String,
    /// Input buffer specs, in call order.
    pub inputs: Vec<IoSpec>,
    /// Output buffer specs, in result order.
    pub outputs: Vec<IoSpec>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    /// Directory the manifest was loaded from.
    pub dir: PathBuf,
    /// Artifact profile (`dev` | `full`).
    pub profile: String,
    /// Similarity-kernel γ baked into the graphs.
    pub gamma: f64,
    /// Relative ridge regularisation of the training solve.
    pub ridge_rel: f64,
    /// Newton–Schulz iterations in the trained inverse.
    pub ns_iters: usize,
    /// Default observation-chunk rows.
    pub chunk: usize,
    /// Every lowered executable in the bundle.
    pub artifacts: Vec<ArtifactMeta>,
}

fn io_specs(v: &Json) -> anyhow::Result<Vec<IoSpec>> {
    v.as_arr()
        .ok_or_else(|| anyhow::anyhow!("io spec not an array"))?
        .iter()
        .map(|e| {
            Ok(IoSpec {
                name: e.req("name")?.as_str().unwrap_or_default().to_string(),
                shape: e
                    .req("shape")?
                    .as_arr()
                    .ok_or_else(|| anyhow::anyhow!("shape not an array"))?
                    .iter()
                    .map(|d| d.as_usize().ok_or_else(|| anyhow::anyhow!("bad dim")))
                    .collect::<anyhow::Result<Vec<_>>>()?,
            })
        })
        .collect()
}

impl Manifest {
    /// Load `manifest.json` from an artifact directory.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            anyhow::anyhow!(
                "cannot read {} ({e}); run `make artifacts` first",
                path.display()
            )
        })?;
        Manifest::parse(&text, dir)
    }

    /// Parse manifest JSON (separated from I/O for testing).
    pub fn parse(text: &str, dir: PathBuf) -> anyhow::Result<Manifest> {
        let root = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let version = root.req("version")?.as_usize().unwrap_or(0);
        anyhow::ensure!(version == 1, "unsupported manifest version {version}");
        let artifacts = root
            .req("artifacts")?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("artifacts not an array"))?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    id: a.req("id")?.as_str().unwrap_or_default().to_string(),
                    graph: a.req("graph")?.as_str().unwrap_or_default().to_string(),
                    n: a.req("n")?.as_usize().unwrap_or(0),
                    m: a.req("m")?.as_usize().unwrap_or(0),
                    chunk: a.req("chunk")?.as_usize().unwrap_or(0),
                    file: a.req("file")?.as_str().unwrap_or_default().to_string(),
                    inputs: io_specs(a.req("inputs")?)?,
                    outputs: io_specs(a.req("outputs")?)?,
                })
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(Manifest {
            dir,
            profile: root
                .req("profile")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            gamma: root.req("gamma")?.as_f64().unwrap_or(0.5),
            ridge_rel: root.req("ridge_rel")?.as_f64().unwrap_or(1e-3),
            ns_iters: root.req("ns_iters")?.as_usize().unwrap_or(30),
            chunk: root.req("chunk")?.as_usize().unwrap_or(0),
            artifacts,
        })
    }

    /// Look up an artifact by graph family and bucket.
    pub fn find(&self, graph: &str, n: usize, m: usize) -> Option<&ArtifactMeta> {
        self.artifacts
            .iter()
            .find(|a| a.graph == graph && a.n == n && a.m == m)
    }

    /// All (n, m) buckets available for a graph family, sorted by capacity.
    pub fn buckets(&self, graph: &str) -> Vec<(usize, usize)> {
        let mut v: Vec<(usize, usize)> = self
            .artifacts
            .iter()
            .filter(|a| a.graph == graph)
            .map(|a| (a.n, a.m))
            .collect();
        v.sort_by_key(|&(n, m)| (n * m, n, m));
        v
    }

    /// Absolute path of an artifact's HLO file.
    pub fn hlo_path(&self, art: &ArtifactMeta) -> PathBuf {
        self.dir.join(&art.file)
    }
}

#[cfg(test)]
pub(crate) const TEST_MANIFEST: &str = r#"{
  "version": 1, "profile": "dev", "gamma": 0.5, "ridge_rel": 0.001,
  "ns_iters": 30, "chunk": 32, "signals": [8, 16], "memvecs": [32, 64],
  "artifacts": [
    {"id": "mset2_train_n8_m32", "graph": "mset2_train", "n": 8, "m": 32,
     "chunk": 32, "file": "mset2_train_n8_m32.hlo.txt",
     "inputs": [{"name": "d", "shape": [32, 8]}, {"name": "mask", "shape": [32]},
                {"name": "bw", "shape": [1]}],
     "outputs": [{"name": "g", "shape": [32, 32]}]},
    {"id": "mset2_train_n16_m64", "graph": "mset2_train", "n": 16, "m": 64,
     "chunk": 32, "file": "mset2_train_n16_m64.hlo.txt",
     "inputs": [{"name": "d", "shape": [64, 16]}, {"name": "mask", "shape": [64]},
                {"name": "bw", "shape": [1]}],
     "outputs": [{"name": "g", "shape": [64, 64]}]},
    {"id": "mset2_surveil_n8_m32", "graph": "mset2_surveil", "n": 8, "m": 32,
     "chunk": 32, "file": "mset2_surveil_n8_m32.hlo.txt",
     "inputs": [{"name": "d", "shape": [32, 8]}, {"name": "g", "shape": [32, 32]},
                {"name": "mask", "shape": [32]}, {"name": "bw", "shape": [1]},
                {"name": "x", "shape": [32, 8]}],
     "outputs": [{"name": "xhat", "shape": [32, 8]}, {"name": "resid", "shape": [32, 8]}]}
  ]
}"#;

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::parse(TEST_MANIFEST, PathBuf::from("/tmp/a")).unwrap()
    }

    #[test]
    fn parses_fields() {
        let m = manifest();
        assert_eq!(m.profile, "dev");
        assert_eq!(m.chunk, 32);
        assert_eq!(m.artifacts.len(), 3);
        assert!((m.gamma - 0.5).abs() < 1e-12);
    }

    #[test]
    fn find_and_buckets() {
        let m = manifest();
        assert!(m.find("mset2_train", 8, 32).is_some());
        assert!(m.find("mset2_train", 8, 33).is_none());
        let b = m.buckets("mset2_train");
        assert_eq!(b, vec![(8, 32), (16, 64)]);
    }

    #[test]
    fn io_specs_parsed() {
        let m = manifest();
        let art = m.find("mset2_surveil", 8, 32).unwrap();
        assert_eq!(art.inputs.len(), 5);
        assert_eq!(art.inputs[4].shape, vec![32, 8]);
        assert_eq!(art.outputs[0].name, "xhat");
    }

    #[test]
    fn rejects_bad_version() {
        let text = TEST_MANIFEST.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&text, PathBuf::from(".")).is_err());
    }

    #[test]
    fn missing_file_message_mentions_make() {
        let err = Manifest::load("/nonexistent-dir-xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }
}
