//! Dependency-free HTTP/1.1 server core.
//!
//! `hyper`/`axum` are unavailable in the offline build environment; the
//! service's needs are small — parse requests, dispatch to a handler,
//! write JSON or streamed responses — so a std `TcpListener` accept loop
//! fanning connections out over
//! [`crate::util::threadpool::TrialExecutor`] covers them (one registered
//! job holds the connection queue).
//!
//! Protocol subset (documented, deliberate):
//! - HTTP/1.1 keep-alive with pipelining: one persistent buffered reader
//!   per connection parses requests back-to-back off the socket, so bytes
//!   of a pipelined next request buffered behind the current one are never
//!   lost. `Connection: close`, HTTP/1.0 without `keep-alive`, a
//!   per-connection request cap, or any framing error closes.
//! - bodies arrive either buffered under `Content-Length` or as
//!   `Transfer-Encoding: chunked`, which is decoded incrementally and fed
//!   straight through [`crate::util::json::stream`] — the raw bytes are
//!   never materialised, only the parsed [`Json`] value, under the same
//!   total-size budget.
//! - requests carrying *both* `Content-Length` and chunked transfer
//!   encoding (or conflicting duplicate `Content-Length` values) are
//!   rejected with 400: ambiguous framing is the classic
//!   request-smuggling vector.
//! - responses are either a buffered body with `Content-Length` or a
//!   [`BodyStream`] written with chunked transfer encoding (NDJSON/SSE
//!   event feeds, row-streamed CSV); a client disconnect mid-stream fails
//!   cleanly — the producer is dropped, the pending-connection slot is
//!   freed, and the outcome is access-logged.
//! - no percent-decoding — all structured data travels in JSON bodies.

use crate::metrics::Registry;
use crate::util::json::{stream, Json};
use crate::util::threadpool::TrialExecutor;
use std::fmt;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Largest accepted request body (buffered or cumulative chunked).
const MAX_BODY_BYTES: usize = 1 << 20;
/// Largest accepted request line + headers, in bytes.
const MAX_HEAD_BYTES: usize = 8 << 10;
/// Largest accepted header count (and chunked-trailer line count).
const MAX_HEADERS: usize = 64;
/// Per-read socket timeout; also the keep-alive idle timeout while
/// waiting for the next request on a persistent connection.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Per-write socket timeout (a stalled reader cannot pin a worker).
const WRITE_TIMEOUT: Duration = Duration::from_secs(10);
/// Whole-request deadline (defeats byte-at-a-time trickle within the
/// per-read timeout). Applies per request, not per connection.
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);
/// Connections admitted concurrently (handling + queued for a pool
/// thread); beyond this the accept loop answers 503 and closes rather
/// than buffering sockets without bound.
const MAX_PENDING_CONNS: usize = 64;

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    /// HTTP method (upper-case).
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw `k=v` query pairs (no percent-decoding).
    pub query: Vec<(String, String)>,
    /// Lower-cased header names with trimmed values.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes (empty for chunked uploads, which are parsed
    /// incrementally into [`Request::body_json`] instead).
    pub body: Vec<u8>,
    /// Body parsed incrementally while a chunked upload was decoded; the
    /// raw bytes were never materialised.
    pub body_json: Option<Json>,
    /// True when the request line declared HTTP/1.1 (HTTP/1.0 defaults to
    /// `Connection: close` semantics).
    pub http11: bool,
}

impl Request {
    /// First query-string value for `key`.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (errors on invalid encodings).
    pub fn body_str(&self) -> anyhow::Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow::anyhow!("body is not valid UTF-8"))
    }

    /// The body as JSON: the incrementally parsed value for chunked
    /// uploads, otherwise the buffered bytes parsed in batch.
    pub fn json_body(&self) -> anyhow::Result<Json> {
        if let Some(j) = &self.body_json {
            return Ok(j.clone());
        }
        Json::parse(self.body_str()?).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// First header value for `name` (header names are stored
    /// lower-cased; pass `name` in lower case).
    pub fn header_get(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// The request's correlation ID: the first non-empty `x-request-id`
    /// header. The connection handler mints one when the client sent
    /// none, so handlers always observe `Some`.
    pub fn request_id(&self) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, v)| k == "x-request-id" && !v.trim().is_empty())
            .map(|(_, v)| v.as_str())
    }

    /// The request's distributed-trace context: a valid W3C
    /// `traceparent` header wins (trace id + caller span id); otherwise
    /// the correlation ID (`x-request-id`) seeds a trace with no parent
    /// span. `None` only before the connection handler injects a minted
    /// request ID, so handlers always observe `Some`.
    pub fn trace_context(&self) -> Option<crate::obs::TraceContext> {
        if let Some(tp) = self.header_get("traceparent") {
            if let Some(ctx) = crate::obs::TraceContext::parse_traceparent(tp) {
                return Some(ctx);
            }
        }
        self.request_id().map(crate::obs::TraceContext::from_id)
    }

    /// Whether this request asks the connection to close afterwards
    /// (explicit `Connection: close`, or HTTP/1.0 without `keep-alive`).
    fn wants_close(&self) -> bool {
        let conn = self.header_get("connection").unwrap_or("");
        let has = |tok: &str| conn.split(',').any(|t| t.trim().eq_ignore_ascii_case(tok));
        if has("close") {
            return true;
        }
        !self.http11 && !has("keep-alive")
    }
}

/// Producer side of a chunked (streamed) response body.
///
/// [`Response`] writes each returned chunk as one HTTP chunk frame and
/// terminates the stream on `Ok(None)`. An `Err` aborts the connection
/// without the final zero-length frame, so the client observes
/// truncation rather than a silently complete body. Implementations are
/// dropped as soon as the stream ends for any reason (including a client
/// disconnect mid-body), so `Drop` is the place to release resources
/// such as event-bus subscriptions.
pub trait BodyStream: Send {
    /// Produce the next chunk; `Ok(None)` ends the stream cleanly.
    /// Empty chunks are skipped (a zero-length HTTP chunk would
    /// terminate the encoding early).
    fn next_chunk(&mut self) -> std::io::Result<Option<Vec<u8>>>;
}

/// Adapts any chunk iterator into a [`BodyStream`] (row-streamed CSV,
/// pre-framed NDJSON segments, …).
pub struct IterBody {
    iter: Box<dyn Iterator<Item = Vec<u8>> + Send>,
}

impl IterBody {
    /// Wrap `iter`; each item becomes one chunk.
    pub fn new(iter: impl Iterator<Item = Vec<u8>> + Send + 'static) -> IterBody {
        IterBody {
            iter: Box::new(iter),
        }
    }
}

impl BodyStream for IterBody {
    fn next_chunk(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        Ok(self.iter.next())
    }
}

/// A response ready to serialize: either a buffered body (written with
/// `Content-Length`) or a streamed one (written with
/// `Transfer-Encoding: chunked`).
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Raw body bytes (ignored when `stream` is set).
    pub body: Vec<u8>,
    /// Streamed body producer; `Some` switches the writer to chunked
    /// transfer encoding.
    pub stream: Option<Box<dyn BodyStream>>,
}

impl fmt::Debug for Response {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Response")
            .field("status", &self.status)
            .field("content_type", &self.content_type)
            .field("body", &self.body)
            .field("stream", &self.stream.as_ref().map(|_| "<BodyStream>"))
            .finish()
    }
}

impl Response {
    /// JSON response with the given status.
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
            stream: None,
        }
    }

    /// Plain-text response with the given status.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            stream: None,
        }
    }

    /// Streamed 200 response written with chunked transfer encoding.
    pub fn streamed(content_type: &'static str, stream: Box<dyn BodyStream>) -> Response {
        Response {
            status: 200,
            content_type,
            body: Vec::new(),
            stream: Some(stream),
        }
    }

    /// JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "",
        }
    }

    /// One-shot close-mode write (accept-loop load shedding).
    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            Response::reason(self.status),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }

    /// Write the response with keep-alive-aware framing. Returns the body
    /// bytes written. Consumes `self.stream` when present; an `Err`
    /// mid-stream means framing is broken and the connection must close.
    fn write_framed(
        &mut self,
        w: &mut dyn Write,
        request_id: Option<&str>,
        traceparent: Option<&str>,
        keep_alive: bool,
    ) -> std::io::Result<u64> {
        let mut rid = match request_id {
            Some(id) => format!("x-request-id: {id}\r\n"),
            None => String::new(),
        };
        if let Some(tp) = traceparent {
            rid.push_str(&format!("traceparent: {tp}\r\n"));
        }
        let conn = if keep_alive { "keep-alive" } else { "close" };
        match self.stream.take() {
            None => {
                let head = format!(
                    "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{rid}Connection: {conn}\r\n\r\n",
                    self.status,
                    Response::reason(self.status),
                    self.content_type,
                    self.body.len()
                );
                w.write_all(head.as_bytes())?;
                w.write_all(&self.body)?;
                w.flush()?;
                Ok(self.body.len() as u64)
            }
            Some(mut body) => {
                let head = format!(
                    "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\n{rid}Connection: {conn}\r\n\r\n",
                    self.status,
                    Response::reason(self.status),
                    self.content_type,
                );
                w.write_all(head.as_bytes())?;
                let mut total = 0u64;
                loop {
                    match body.next_chunk()? {
                        Some(chunk) => {
                            if chunk.is_empty() {
                                continue;
                            }
                            write!(w, "{:x}\r\n", chunk.len())?;
                            w.write_all(&chunk)?;
                            w.write_all(b"\r\n")?;
                            w.flush()?;
                            total += chunk.len() as u64;
                        }
                        None => {
                            w.write_all(b"0\r\n\r\n")?;
                            w.flush()?;
                            return Ok(total);
                        }
                    }
                }
            }
        }
    }
}

/// A `Read` over a borrowed `TcpStream` that enforces an absolute deadline:
/// every read gets a socket timeout of `min(remaining, READ_TIMEOUT)`, so a
/// byte-at-a-time trickle cannot hold a handler thread past the deadline.
/// The deadline is re-armed per request by the connection loop.
struct DeadlineStream<'a> {
    stream: &'a TcpStream,
    deadline: Instant,
}

impl Read for DeadlineStream<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let remaining = self.deadline.saturating_duration_since(Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request deadline exceeded",
            ));
        }
        self.stream
            .set_read_timeout(Some(remaining.min(READ_TIMEOUT)))?;
        (&mut &*self.stream).read(buf)
    }
}

/// Why a request could not be read off the connection.
enum ReadError {
    /// Clean end between requests: EOF before any request bytes, or the
    /// keep-alive idle timeout elapsed. Close silently.
    Idle,
    /// Protocol violation worth a 400 before closing.
    Bad(String),
}

impl From<anyhow::Error> for ReadError {
    fn from(e: anyhow::Error) -> ReadError {
        ReadError::Bad(e.to_string())
    }
}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> ReadError {
        ReadError::Bad(e.to_string())
    }
}

/// Read one LF-terminated line without ever buffering more than `cap`
/// bytes, returning it with the trailing `\r?\n` stripped. `Ok(None)`
/// means EOF arrived before any byte of the line.
fn read_line_bounded(r: &mut impl BufRead, cap: usize) -> std::io::Result<Option<String>> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let (done, used) = {
            let buf = r.fill_buf()?;
            if buf.is_empty() {
                if line.is_empty() {
                    return Ok(None);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "eof mid-line",
                ));
            }
            match buf.iter().position(|&b| b == b'\n') {
                Some(i) => {
                    line.extend_from_slice(&buf[..i]);
                    (true, i + 1)
                }
                None => {
                    line.extend_from_slice(buf);
                    (false, buf.len())
                }
            }
        };
        r.consume(used);
        if line.len() > cap {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "line too long",
            ));
        }
        if done {
            if line.last() == Some(&b'\r') {
                line.pop();
            }
            return String::from_utf8(line)
                .map(Some)
                .map_err(|_| std::io::Error::new(std::io::ErrorKind::InvalidData, "non-utf8 line"));
        }
    }
}

/// Decode a `Transfer-Encoding: chunked` body off `reader`, feeding every
/// data byte through the incremental JSON parser so the raw body is never
/// materialised. Returns the parsed value (`None` for an empty body).
fn read_chunked_json(
    reader: &mut BufReader<DeadlineStream<'_>>,
) -> Result<Option<Json>, ReadError> {
    let limits = stream::Limits {
        max_depth: 256,
        max_token_bytes: MAX_BODY_BYTES,
    };
    let mut parser = stream::StreamParser::new(limits);
    let mut builder = stream::ValueBuilder::new();
    let mut events = Vec::new();
    let mut total = 0usize;
    let mut feed = |parser: &mut stream::StreamParser,
                    builder: &mut stream::ValueBuilder,
                    events: &mut Vec<stream::Event>,
                    bytes: &[u8]|
     -> Result<(), ReadError> {
        parser
            .feed(bytes, events)
            .map_err(|e| ReadError::Bad(format!("chunked body: {e}")))?;
        for ev in events.drain(..) {
            builder
                .on_event(ev)
                .map_err(|e| ReadError::Bad(format!("chunked body: {e}")))?;
        }
        Ok(())
    };
    loop {
        let size_line = read_line_bounded(reader, 128)?
            .ok_or_else(|| ReadError::Bad("eof in chunk size".to_string()))?;
        let hex = size_line.split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(hex, 16)
            .map_err(|_| ReadError::Bad(format!("bad chunk size '{hex}'")))?;
        if size == 0 {
            // Trailer section: bounded header-shaped lines up to a blank.
            for _ in 0..=MAX_HEADERS {
                match read_line_bounded(reader, 1 << 10)? {
                    Some(l) if l.is_empty() => {
                        if total == 0 {
                            return Ok(None);
                        }
                        let mut events = Vec::new();
                        parser
                            .finish(&mut events)
                            .map_err(|e| ReadError::Bad(format!("chunked body: {e}")))?;
                        for ev in events.drain(..) {
                            builder
                                .on_event(ev)
                                .map_err(|e| ReadError::Bad(format!("chunked body: {e}")))?;
                        }
                        return builder
                            .take()
                            .map(Some)
                            .ok_or_else(|| ReadError::Bad("chunked body: incomplete".to_string()));
                    }
                    Some(_) => continue,
                    None => return Err(ReadError::Bad("eof in trailers".to_string())),
                }
            }
            return Err(ReadError::Bad("too many trailer lines".to_string()));
        }
        total = total
            .checked_add(size)
            .filter(|&t| t <= MAX_BODY_BYTES)
            .ok_or_else(|| ReadError::Bad(format!("chunked body too large (> {MAX_BODY_BYTES})")))?;
        // Stream the chunk data through the parser in bounded slices.
        let mut remaining = size;
        let mut scratch = [0u8; 8 << 10];
        while remaining > 0 {
            let n = remaining.min(scratch.len());
            reader.read_exact(&mut scratch[..n])?;
            feed(&mut parser, &mut builder, &mut events, &scratch[..n])?;
            remaining -= n;
        }
        let mut crlf = [0u8; 2];
        reader.read_exact(&mut crlf)?;
        if &crlf != b"\r\n" {
            return Err(ReadError::Bad("missing chunk terminator".to_string()));
        }
    }
}

/// Parse one request off the persistent connection reader. Pipelined
/// bytes already buffered in `reader` are consumed before the socket is
/// touched again, so back-to-back requests written in one segment are
/// each served in order.
fn read_request(reader: &mut BufReader<DeadlineStream<'_>>) -> Result<Request, ReadError> {
    let line = match read_line_bounded(reader, MAX_HEAD_BYTES) {
        Ok(Some(l)) => l,
        // EOF or idle timeout between requests: normal keep-alive end.
        Ok(None) => return Err(ReadError::Idle),
        Err(e)
            if matches!(
                e.kind(),
                std::io::ErrorKind::TimedOut
                    | std::io::ErrorKind::WouldBlock
                    | std::io::ErrorKind::ConnectionReset
            ) =>
        {
            return Err(ReadError::Idle)
        }
        Err(e) => return Err(ReadError::Bad(e.to_string())),
    };
    let mut head_bytes = line.len();
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| ReadError::Bad("empty request line".to_string()))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| ReadError::Bad("missing request target".to_string()))?
        .to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        return Err(ReadError::Bad(format!("unsupported protocol '{version}'")));
    }
    let http11 = version == "HTTP/1.1";

    let mut headers: Vec<(String, String)> = Vec::new();
    let mut content_len: Option<usize> = None;
    let mut chunked = false;
    loop {
        let h = read_line_bounded(reader, MAX_HEAD_BYTES)?
            .ok_or_else(|| ReadError::Bad("unexpected eof in headers".to_string()))?;
        head_bytes += h.len() + 2;
        if head_bytes > MAX_HEAD_BYTES {
            return Err(ReadError::Bad("request head too large".to_string()));
        }
        if h.is_empty() {
            break;
        }
        let (k, v) = h
            .split_once(':')
            .ok_or_else(|| ReadError::Bad("malformed header line".to_string()))?;
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim().to_string();
        if k == "content-length" {
            let n: usize = v
                .parse()
                .map_err(|_| ReadError::Bad(format!("bad content-length '{v}'")))?;
            // Conflicting duplicate Content-Length headers are the other
            // classic smuggling vector; identical repeats are tolerated.
            if content_len.is_some_and(|prev| prev != n) {
                return Err(ReadError::Bad(
                    "conflicting content-length headers".to_string(),
                ));
            }
            content_len = Some(n);
        }
        if k == "transfer-encoding" {
            if !v.trim().eq_ignore_ascii_case("chunked") {
                return Err(ReadError::Bad(format!("unsupported transfer-encoding '{v}'")));
            }
            chunked = true;
        }
        headers.push((k, v));
        if headers.len() > MAX_HEADERS {
            return Err(ReadError::Bad("too many headers".to_string()));
        }
    }
    // Request-smuggling guard: a message with both framings is ambiguous
    // (RFC 9112 §6.3) — reject instead of picking one.
    if chunked && content_len.is_some() {
        return Err(ReadError::Bad(
            "both content-length and transfer-encoding present".to_string(),
        ));
    }

    let (body, body_json) = if chunked {
        (Vec::new(), read_chunked_json(reader)?)
    } else {
        let n = content_len.unwrap_or(0);
        if n > MAX_BODY_BYTES {
            return Err(ReadError::Bad(format!("body too large ({n} bytes)")));
        }
        let mut body = vec![0u8; n];
        reader.read_exact(&mut body)?;
        (body, None)
    };

    let (path, query_raw) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.clone(), ""),
    };
    let query = query_raw
        .split('&')
        .filter(|p| !p.is_empty())
        .map(|p| {
            let (k, v) = p.split_once('=').unwrap_or((p, ""));
            (k.to_string(), v.to_string())
        })
        .collect();
    Ok(Request {
        method,
        path,
        query,
        headers,
        body,
        body_json,
        http11,
    })
}

/// Connection handler signature: pure request → response.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync + 'static>;

/// Connection-handling options.
#[derive(Clone)]
pub struct HttpOptions {
    /// Keep connections open between requests (HTTP/1.1 persistent
    /// connections). When false every response carries
    /// `Connection: close`, restoring the pre-streaming one-shot model.
    pub keep_alive: bool,
    /// Requests served per connection before the server forces a close
    /// (bounds how long one client can pin a worker).
    pub max_requests_per_conn: usize,
    /// Advisory shed-early signal consulted by the accept loop: while it
    /// returns true (e.g. an SLO burn-rate page), load shedding trips at
    /// a quarter of the normal pending-connection cap, so an overloaded
    /// service starts refusing work before the queue is saturated.
    pub shed_advisor: Option<Arc<dyn Fn() -> bool + Send + Sync>>,
}

impl fmt::Debug for HttpOptions {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("HttpOptions")
            .field("keep_alive", &self.keep_alive)
            .field("max_requests_per_conn", &self.max_requests_per_conn)
            .field("shed_advisor", &self.shed_advisor.as_ref().map(|_| "<fn>"))
            .finish()
    }
}

impl Default for HttpOptions {
    fn default() -> Self {
        HttpOptions {
            keep_alive: true,
            max_requests_per_conn: 1024,
            shed_advisor: None,
        }
    }
}

/// Monotonic connection ids for the access log (`conn=` field), joining
/// the requests multiplexed over one keep-alive connection.
static CONN_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(1);

/// Serve requests off one connection until it closes.
///
/// Access-log format (target `http.access`, one line per request):
///
/// ```text
/// <METHOD> <path> <status> <latency>ms [streamed ]<bytes>b \
///     id=<request-id> trace=<trace-id> conn=<connection-id>
/// ```
///
/// `<bytes>` is the response body bytes actually written (`aborted: <e>`
/// replaces it when the client vanished mid-body); `trace=` carries the
/// request's trace id (from `traceparent` or `x-request-id`), so one
/// line joins logs ↔ traces ↔ metrics; `conn=` groups the requests
/// pipelined over one keep-alive connection.
fn handle_connection(stream: TcpStream, handler: Handler, opts: HttpOptions) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let _ = stream.set_write_timeout(Some(WRITE_TIMEOUT));
    let _ = stream.set_nodelay(true);
    let conn_id = CONN_SEQ.fetch_add(1, Ordering::Relaxed);
    let mut reader = BufReader::with_capacity(
        8 << 10,
        DeadlineStream {
            stream: &stream,
            deadline: Instant::now() + REQUEST_DEADLINE,
        },
    );
    let mut served = 0usize;
    loop {
        reader.get_mut().deadline = Instant::now() + REQUEST_DEADLINE;
        let t0 = Instant::now();
        let (mut resp, request_id, ctx, line, keep) = match read_request(&mut reader) {
            Ok(mut req) => {
                served += 1;
                // Honour the caller's correlation ID; mint one otherwise
                // and inject it so handlers observe the same ID the
                // access log and response header carry.
                let rid = match req.request_id() {
                    Some(id) => id.to_string(),
                    None => {
                        let id = crate::obs::mint_trace_id();
                        req.headers.push(("x-request-id".to_string(), id.clone()));
                        id
                    }
                };
                let line = format!("{} {}", req.method, req.path);
                let keep = opts.keep_alive
                    && served < opts.max_requests_per_conn
                    && !req.wants_close();
                let ctx = req.trace_context();
                ((*handler)(&req), rid, ctx, line, keep)
            }
            Err(ReadError::Idle) => return,
            Err(ReadError::Bad(e)) => (
                Response::error(400, &format!("bad request: {e}")),
                crate::obs::mint_trace_id(),
                None,
                "<unparsed>".to_string(),
                // Framing is unreliable after a parse error; never reuse.
                false,
            ),
        };
        let streamed = resp.stream.is_some();
        let status = resp.status;
        // Echo the trace as a response `traceparent`, under a span id
        // minted for this HTTP exchange — the access-log line below is
        // that span's record.
        let tp = ctx
            .as_ref()
            .map(|c| c.traceparent(crate::obs::mint_span_id()));
        let wrote = resp.write_framed(&mut (&stream), Some(&request_id), tp.as_deref(), keep);
        let elapsed = t0.elapsed();
        let reg = Registry::global();
        reg.time("service.http.request_seconds", elapsed);
        reg.inc(match status / 100 {
            2 => "service.http.responses.2xx",
            4 => "service.http.responses.4xx",
            5 => "service.http.responses.5xx",
            _ => "service.http.responses.other",
        });
        if streamed {
            reg.inc("service.http.streams");
        }
        if crate::obs::access_log_enabled() {
            let outcome = match &wrote {
                Ok(bytes) => format!("{bytes}b"),
                Err(e) => format!("aborted: {e}"),
            };
            let trace = ctx.as_ref().map(|c| c.trace_id.as_str()).unwrap_or("-");
            log::info!(
                target: "http.access",
                "{line} {status} {:.3}ms {}{outcome} id={request_id} trace={trace} conn={conn_id}",
                elapsed.as_secs_f64() * 1e3,
                if streamed { "streamed " } else { "" },
            );
        }
        match wrote {
            Ok(_) if keep => continue,
            Ok(_) => return,
            Err(e) => {
                // Client disconnect mid-body (or a producer failure): the
                // stream producer has already been dropped by
                // write_framed, so subscriptions are released; close.
                log::debug!("http: response write failed: {e}");
                return;
            }
        }
    }
}

/// Accept loop + connection thread pool over a generic [`Handler`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind with default [`HttpOptions`] (keep-alive on).
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> anyhow::Result<HttpServer> {
        HttpServer::bind_with(addr, workers, handler, HttpOptions::default())
    }

    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and serve
    /// connections on `workers` pool threads until shutdown/drop.
    pub fn bind_with(
        addr: &str,
        workers: usize,
        handler: Handler,
        opts: HttpOptions,
    ) -> anyhow::Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                let pool = TrialExecutor::new(workers.max(1), false);
                let conns = pool.register(1.0);
                let pending = Arc::new(AtomicUsize::new(0));
                let mut accepted: u64 = 0;
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            // Chaos hook: a deterministic accept fault
                            // behaves like a connection reset — the socket
                            // is dropped, the loop keeps serving. The tag
                            // varies per connection so at rate<1 a client
                            // retry succeeds (`hit_no_panic`: this thread
                            // must never unwind).
                            accepted += 1;
                            if let Err(e) = crate::util::failpoint::hit_no_panic(
                                "http.conn.accept",
                                accepted,
                            ) {
                                Registry::global().inc("service.http.accept_faults");
                                log::debug!("http: injected accept fault: {e:#}");
                                drop(stream);
                                continue;
                            }
                            // Advisory shed-early: while the SLO engine
                            // pages, trip the same 503 path at a quarter
                            // of the normal queue depth.
                            let cap = match &opts.shed_advisor {
                                Some(advise) if advise() => MAX_PENDING_CONNS / 4,
                                _ => MAX_PENDING_CONNS,
                            };
                            if pending.load(Ordering::SeqCst) >= cap {
                                // Shed load instead of buffering sockets
                                // without bound behind a busy pool.
                                let reg = Registry::global();
                                reg.inc("service.http.responses.5xx");
                                reg.inc("service.http.shed");
                                if cap < MAX_PENDING_CONNS {
                                    reg.inc("service.http.shed.slo");
                                }
                                let _ = Response::error(503, "server busy; retry later")
                                    .write_to(&mut stream);
                                continue;
                            }
                            pending.fetch_add(1, Ordering::SeqCst);
                            let h = Arc::clone(&handler);
                            let p = Arc::clone(&pending);
                            let o = opts.clone();
                            conns.submit(move || {
                                // A panicking handler must not kill the
                                // pool worker or leak its pending slot.
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(move || {
                                        handle_connection(stream, h, o)
                                    }),
                                );
                                if r.is_err() {
                                    log::error!("http: connection handler panicked");
                                }
                                p.fetch_sub(1, Ordering::SeqCst);
                            });
                        }
                        Err(e) => log::warn!("http: accept failed: {e}"),
                    }
                }
                drop(conns);
                pool.shutdown();
            })?;
        Ok(HttpServer {
            addr: local,
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight connections, join the accept thread.
    pub fn shutdown(self) {
        drop(self);
    }

    /// Block until the accept loop exits (serve-forever mode).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept() so the loop observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    fn echo_server() -> HttpServer {
        let handler: Handler = Arc::new(|req: &Request| {
            let body = match &req.body_json {
                Some(j) => j.to_string(),
                None => req.body_str().unwrap_or("").to_string(),
            };
            Response::json(
                200,
                &Json::obj(vec![
                    ("method", Json::Str(req.method.clone())),
                    ("path", Json::Str(req.path.clone())),
                    (
                        "q",
                        Json::Str(req.query_get("q").unwrap_or("").to_string()),
                    ),
                    ("body", Json::Str(body)),
                    ("chunked", Json::Bool(req.body_json.is_some())),
                ]),
            )
        });
        HttpServer::bind("127.0.0.1:0", 2, handler).unwrap()
    }

    fn raw_roundtrip(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    /// Read one Content-Length-framed response off a keep-alive
    /// connection, returning (head, body).
    fn read_framed_response(r: &mut BufReader<&TcpStream>) -> (String, String) {
        let mut head = String::new();
        let mut content_len = 0usize;
        loop {
            let mut line = String::new();
            r.read_line(&mut line).unwrap();
            if let Some(v) = line.to_ascii_lowercase().strip_prefix("content-length: ") {
                content_len = v.trim().parse().unwrap();
            }
            let done = line == "\r\n";
            head.push_str(&line);
            if done {
                break;
            }
        }
        let mut body = vec![0u8; content_len];
        r.read_exact(&mut body).unwrap();
        (head, String::from_utf8(body).unwrap())
    }

    #[test]
    fn parses_and_echoes_request() {
        let server = echo_server();
        let body = r#"{"x":1}"#;
        let raw = format!(
            "POST /v1/echo?q=7 HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
            body.len()
        );
        let out = raw_roundtrip(server.addr(), &raw);
        assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "{out}");
        let payload = out.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(payload).unwrap();
        assert_eq!(j.get("method").unwrap().as_str(), Some("POST"));
        assert_eq!(j.get("path").unwrap().as_str(), Some("/v1/echo"));
        assert_eq!(j.get("q").unwrap().as_str(), Some("7"));
        assert_eq!(j.get("body").unwrap().as_str(), Some(body));
        server.shutdown();
    }

    #[test]
    fn malformed_requests_get_400() {
        let server = echo_server();
        let out = raw_roundtrip(server.addr(), "NONSENSE\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        let out = raw_roundtrip(
            server.addr(),
            "GET / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        server.shutdown();
    }

    #[test]
    fn request_id_is_honoured_or_minted_and_echoed() {
        let server = echo_server();
        let out = raw_roundtrip(
            server.addr(),
            "GET / HTTP/1.1\r\nHost: t\r\nX-Request-Id: my-id-7\r\nConnection: close\r\n\r\n",
        );
        assert!(out.contains("x-request-id: my-id-7"), "{out}");
        let out = raw_roundtrip(
            server.addr(),
            "GET / HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        );
        let rid = out
            .lines()
            .find_map(|l| l.strip_prefix("x-request-id: "))
            .expect("minted id echoed");
        assert!(!rid.trim().is_empty(), "{out}");
        server.shutdown();
    }

    #[test]
    fn concurrent_connections_are_served() {
        let server = echo_server();
        let addr = server.addr();
        std::thread::scope(|scope| {
            for i in 0..8 {
                scope.spawn(move || {
                    let raw =
                        format!("GET /c/{i} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n");
                    let out = raw_roundtrip(addr, &raw);
                    assert!(out.contains(&format!("/c/{i}")), "{out}");
                });
            }
        });
        server.shutdown();
    }

    #[test]
    fn shutdown_unblocks_accept() {
        let server = echo_server();
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5), "shutdown hung");
    }

    #[test]
    fn keep_alive_serves_pipelined_requests() {
        let server = echo_server();
        let stream = TcpStream::connect(server.addr()).unwrap();
        // Two requests written back-to-back in one segment (pipelined),
        // then a third after the first responses arrive.
        (&stream)
            .write_all(
                b"GET /a HTTP/1.1\r\nHost: t\r\n\r\nGET /b HTTP/1.1\r\nHost: t\r\n\r\n",
            )
            .unwrap();
        let mut r = BufReader::new(&stream);
        let (head_a, body_a) = read_framed_response(&mut r);
        assert!(head_a.starts_with("HTTP/1.1 200 OK"), "{head_a}");
        assert!(head_a.contains("Connection: keep-alive"), "{head_a}");
        assert!(body_a.contains("\"/a\""), "{body_a}");
        let (_, body_b) = read_framed_response(&mut r);
        assert!(body_b.contains("\"/b\""), "{body_b}");
        (&stream)
            .write_all(b"GET /c HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n")
            .unwrap();
        let (head_c, body_c) = read_framed_response(&mut r);
        assert!(head_c.contains("Connection: close"), "{head_c}");
        assert!(body_c.contains("\"/c\""), "{body_c}");
        server.shutdown();
    }

    #[test]
    fn http10_defaults_to_close() {
        let server = echo_server();
        let out = raw_roundtrip(server.addr(), "GET /x HTTP/1.0\r\nHost: t\r\n\r\n");
        assert!(out.contains("Connection: close"), "{out}");
        server.shutdown();
    }

    #[test]
    fn smuggling_ambiguous_framing_rejected() {
        let server = echo_server();
        // Content-Length + Transfer-Encoding: chunked → ambiguous → 400.
        let out = raw_roundtrip(
            server.addr(),
            "POST /v1/echo HTTP/1.1\r\nHost: t\r\nContent-Length: 5\r\nTransfer-Encoding: chunked\r\n\r\n0\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        assert!(out.contains("transfer-encoding"), "{out}");
        // Conflicting duplicate Content-Length values → 400.
        let out = raw_roundtrip(
            server.addr(),
            "POST /v1/echo HTTP/1.1\r\nHost: t\r\nContent-Length: 3\r\nContent-Length: 4\r\n\r\nabcd",
        );
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        // Unknown transfer encodings → 400 rather than misframed reads.
        let out = raw_roundtrip(
            server.addr(),
            "POST /v1/echo HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: gzip\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        server.shutdown();
    }

    #[test]
    fn chunked_request_body_is_stream_parsed() {
        let server = echo_server();
        let stream = TcpStream::connect(server.addr()).unwrap();
        // Body {"x":[1,2]} split across three chunks at awkward points.
        (&stream)
            .write_all(
                b"POST /v1/echo HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\nConnection: close\r\n\r\n4\r\n{\"x\"\r\n5\r\n:[1,2\r\n2\r\n]}\r\n0\r\n\r\n",
            )
            .unwrap();
        let mut out = String::new();
        (&stream).read_to_string(&mut out).unwrap();
        assert!(out.starts_with("HTTP/1.1 200 OK"), "{out}");
        let payload = out.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(payload).unwrap();
        assert_eq!(j.get("chunked").unwrap().as_bool(), Some(true));
        assert_eq!(j.get("body").unwrap().as_str(), Some(r#"{"x":[1,2]}"#));
        server.shutdown();
    }

    #[test]
    fn chunked_request_invalid_json_rejected() {
        let server = echo_server();
        let out = raw_roundtrip(
            server.addr(),
            "POST /v1/echo HTTP/1.1\r\nHost: t\r\nTransfer-Encoding: chunked\r\n\r\n3\r\n{{{\r\n0\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        server.shutdown();
    }

    #[test]
    fn streamed_response_uses_chunked_encoding() {
        let handler: Handler = Arc::new(|_req: &Request| {
            let rows = (0..3).map(|i| format!("row{i}\n").into_bytes());
            Response::streamed("text/plain; charset=utf-8", Box::new(IterBody::new(rows)))
        });
        let server = HttpServer::bind("127.0.0.1:0", 2, handler).unwrap();
        let out = raw_roundtrip(
            server.addr(),
            "GET /rows HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        );
        assert!(out.contains("Transfer-Encoding: chunked"), "{out}");
        for part in ["5\r\nrow0\n\r\n", "5\r\nrow1\n\r\n", "5\r\nrow2\n\r\n", "0\r\n\r\n"] {
            assert!(out.contains(part), "missing {part:?} in {out}");
        }
        server.shutdown();
    }

    /// Mid-body client disconnect: the producer must be dropped (resources
    /// released) and the worker slot freed for the next connection.
    #[test]
    fn client_disconnect_mid_stream_fails_cleanly() {
        struct Endless {
            dropped: Arc<AtomicBool>,
        }
        impl BodyStream for Endless {
            fn next_chunk(&mut self) -> std::io::Result<Option<Vec<u8>>> {
                std::thread::sleep(Duration::from_millis(1));
                Ok(Some(vec![b'x'; 1 << 10]))
            }
        }
        impl Drop for Endless {
            fn drop(&mut self) {
                self.dropped.store(true, Ordering::SeqCst);
            }
        }
        let dropped = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&dropped);
        let hits = Arc::new(Mutex::new(0usize));
        let hits2 = Arc::clone(&hits);
        let handler: Handler = Arc::new(move |req: &Request| {
            *hits2.lock().unwrap() += 1;
            if req.path == "/stream" {
                Response::streamed(
                    "application/x-ndjson",
                    Box::new(Endless {
                        dropped: Arc::clone(&flag),
                    }),
                )
            } else {
                Response::text(200, "ok")
            }
        });
        let server = HttpServer::bind("127.0.0.1:0", 2, handler).unwrap();
        {
            let stream = TcpStream::connect(server.addr()).unwrap();
            (&stream)
                .write_all(b"GET /stream HTTP/1.1\r\nHost: t\r\n\r\n")
                .unwrap();
            let mut first = [0u8; 256];
            let n = (&stream).read(&mut first).unwrap();
            assert!(n > 0, "no stream bytes arrived");
            // Drop the connection mid-body.
        }
        let t0 = Instant::now();
        while !dropped.load(Ordering::SeqCst) {
            assert!(
                t0.elapsed() < Duration::from_secs(15),
                "stream producer never dropped after client disconnect"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        // The server must still serve fresh connections afterwards.
        let out = raw_roundtrip(
            server.addr(),
            "GET /ok HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 200 OK"), "{out}");
        assert!(*hits.lock().unwrap() >= 2);
        server.shutdown();
    }
}
