//! End-to-end pipeline test: TPSS synthesis → device sweep → response
//! surfaces → sensitivity conclusions → shape recommendation → SPRT
//! detection — the whole paper in one test, on a small grid.
//!
//! Requires AOT artifacts (`python/compile/aot.py`); **skips** with a
//! notice when they are absent so the suite stays green on bare checkouts.

use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::detect::{measure, Sprt, SprtConfig};
use containerstress::recommend::{recommend, LocalCalibration, Sla};
use containerstress::runtime::DeviceServer;
use containerstress::shapes::Workload;
use containerstress::surface::ResponseSurface;
use containerstress::tpss::{inject, synthesize, Fault, TpssConfig};

fn dev_spec() -> SweepSpec {
    SweepSpec {
        signals: vec![4, 8, 12, 16],
        memvecs: vec![32, 48, 64],
        obs: vec![64, 128, 256],
        trials: 2,
        seed: 42,
        model: "mset2".into(),
        workers: 4,
        ..SweepSpec::default()
    }
}

#[test]
fn full_pipeline_on_device() {
    let dir = containerstress::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "skipping full_pipeline_on_device: artifacts missing at {} (generate with python/compile/aot.py)",
            dir.display()
        );
        return;
    }
    let server = DeviceServer::start(&dir).expect("device server");
    let spec = dev_spec();
    let result = run_sweep(&spec, Backend::Device(server.handle())).expect("sweep");

    // --- structure -------------------------------------------------------
    assert_eq!(result.cells.len(), 4 * 3 * 3);
    assert!(result.gap_cells().is_empty(), "all dev cells satisfy m ≥ 2n");

    // --- response surfaces + the paper's §III.A conclusions ---------------
    let train_surf = ResponseSurface::fit(&result.samples("train")).unwrap();
    let surveil_surf = ResponseSurface::fit(&result.samples("surveil")).unwrap();
    // Debug-build prep timings are noisy; the release benches demand much
    // tighter fits (see EXPERIMENTS.md), here we only require signal.
    assert!(train_surf.r2 > 0.3, "train surface r² {}", train_surf.r2);
    assert!(
        surveil_surf.r2 > 0.4,
        "surveil surface r² {}",
        surveil_surf.r2
    );
    // Surveillance cost must depend on n_obs (paper: "primarily depends on
    // the number of observations and signals").
    let e = surveil_surf.exponents();
    assert!(
        e[2] > 0.3,
        "surveillance must scale with n_obs: exponents {e:?}"
    );
    // Training cost must be much less obs-sensitive than surveillance.
    let et = train_surf.exponents();
    assert!(
        et[2] < e[2],
        "training obs-sensitivity {et:?} should be below surveillance {e:?}"
    );

    // --- recommendation ---------------------------------------------------
    let cal = LocalCalibration::from_surface(&surveil_surf, 16, 64, 256);
    let rec = recommend(
        &Workload::customer_a(),
        &train_surf,
        &surveil_surf,
        cal,
        &Sla::default(),
    );
    assert!(
        rec.chosen_shape().is_some(),
        "customer A must be schedulable:\n{}",
        rec.render()
    );

    // --- detection through the device path --------------------------------
    let n = 8;
    let cfg = TpssConfig::sized(n, 2048);
    let train_ds = synthesize(&cfg, 100);
    let model = containerstress::mset::train(&train_ds.data, 64).unwrap();
    let mut sess =
        containerstress::runtime::mset::DeviceMset::new(server.handle(), &model.d).unwrap();
    sess.train().unwrap();

    let healthy = synthesize(&cfg, 101);
    let (_, resid_h, _) = sess
        .surveil(&model.scaler.transform(&healthy.data))
        .unwrap();
    // TPSS residuals are serially correlated (deterministic modes + AR
    // noise), which inflates SPRT evidence relative to the iid design
    // theory; deployments compensate by designing for a larger shift and
    // stricter α — same here.
    let mut det = Sprt::from_healthy(
        &resid_h,
        SprtConfig {
            alpha: 1e-6,
            beta: 1e-4,
            shift: 4.5,
            var_ratio: 6.0,
        },
    );

    let mut faulted = synthesize(&cfg, 102);
    let onset = inject(&mut faulted, 3, Fault::Step { magnitude: 5.0 }, 0.5, 103);
    let (_, resid_f, _) = sess
        .surveil(&model.scaler.transform(&faulted.data))
        .unwrap();
    let (far, missed, latency) = measure(&mut det, &resid_f, Some(3), onset);
    assert_eq!(missed, Some(0.0), "5σ step missed by device-path SPRT");
    assert!(far < 5e-3, "false alarm rate {far}");
    assert!(latency.unwrap() < 50, "latency {latency:?}");
}
