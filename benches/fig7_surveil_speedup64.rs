//! **Fig. 7**: GPU surveillance speedup factor for the **64-signal** use
//! case vs (number of observations × number of memory vectors), log–log.
//! Paper: grows non-linearly, "can exceed 5000×".
//!
//! The modelled surface covers the paper's range; measured local
//! surveillance costs over the scaled grid anchor the CPU term (same
//! workflow as fig6).
//!
//! Output: `results/fig7_surveil_speedup64/`.

use containerstress::accel::{self, CpuRef, GpuSpec};
use containerstress::bench::figs;
use containerstress::report;
use containerstress::surface::SurfaceGrid;
use std::path::Path;

const N_SIGNALS: usize = 64;

fn main() {
    containerstress::util::logger::init();
    let gpu = GpuSpec::v100();
    let cpu = CpuRef::xeon_platinum();
    let out = Path::new("results/fig7_surveil_speedup64");

    // --- modelled paper-range surface ---------------------------------------
    let obs_axis: Vec<usize> = (10..=20).step_by(2).map(|k| 1usize << k).collect();
    let memvecs: Vec<usize> = (7..=13).map(|k| 1usize << k).collect();
    let mut grid = SurfaceGrid::new(
        "n_memvec",
        "n_obs",
        memvecs.iter().map(|&v| v as f64).collect(),
        obs_axis.iter().map(|&v| v as f64).collect(),
    );
    let mut hi = 0.0f64;
    for (r, &m) in memvecs.iter().enumerate() {
        for (c, &obs) in obs_axis.iter().enumerate() {
            let s = accel::speedup_surveil(N_SIGNALS, m, obs, &gpu, &cpu);
            hi = hi.max(s);
            grid.set(r, c, s);
        }
    }
    let ascii = report::emit_figure(
        out,
        "fig7_modelled",
        "Fig7: surveillance speedup @64 signals (modelled, log-log)",
        &grid,
        "speedup",
        true,
    )
    .expect("emit");
    println!("{ascii}");
    println!("peak modelled speedup {hi:.0}× (paper: exceeds 5000×)");
    assert!(hi > 4000.0, "peak {hi} too low vs paper anchor");

    // Non-linear growth with obs (launch-overhead amortisation): probed at
    // small m, where per-kernel overhead is still visible; at large m the
    // speedup saturates immediately — both regimes are visible in Fig. 7.
    let s_small = accel::speedup_surveil(N_SIGNALS, 128, 1 << 10, &gpu, &cpu);
    let s_mid = accel::speedup_surveil(N_SIGNALS, 128, 1 << 16, &gpu, &cpu);
    assert!(
        s_mid > 1.5 * s_small,
        "growth with n_obs missing: {s_small:.0}× → {s_mid:.0}×"
    );

    // --- measured local anchor ----------------------------------------------
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (sig_b, mem_b) = figs::available_axes(&handle);
    // closest available bucket to the 64-signal use case
    let n = *sig_b.iter().min_by_key(|&&s| s.abs_diff(N_SIGNALS)).unwrap();
    let trials = if figs::quick() { 1 } else { 2 };
    let obs_local: Vec<usize> = if figs::quick() {
        vec![256, 1024]
    } else {
        vec![256, 1024, 4096]
    };
    let mut measured = Vec::new();
    for &m in &mem_b {
        if m < 2 * n {
            continue;
        }
        for &obs in &obs_local {
            let t = figs::median(&figs::measure_surveil(&handle, n, m, obs, trials));
            let flops = accel::total_flops(&accel::surveil_routines(n, m, obs, accel::GPU_CHUNK));
            measured.push((flops, t));
        }
    }
    let local_eff = accel::calibrate_cpu_eff(&measured)
        .expect("at least one measured (flops, seconds) surveillance cell");
    println!(
        "local testbed effective surveillance throughput at n={n}: {:.2} GFLOP/s",
        local_eff / 1e9
    );
    let local_cpu = CpuRef {
        train_eff_flops: local_eff,
        surveil_eff_flops: local_eff,
    };
    let s_anchored = accel::speedup_surveil(N_SIGNALS, 8192, 1 << 20, &gpu, &local_cpu);
    println!(
        "anchored to local CPU: peak speedup would be {s_anchored:.0}× \
         (local XLA CPU is multithreaded/vectorised, unlike the paper-era reference)"
    );
    println!("fig7 done → {}", out.display());
}
