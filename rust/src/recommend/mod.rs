//! Scoping recommender: the output stage of ContainerStress.
//!
//! Given a customer workload (signals, memory vectors, sampling rate) and
//! the measured cost surfaces, recommend the cheapest cloud shape that
//! sustains real-time streaming surveillance with headroom, fits the MSET
//! memory footprint, and (optionally) compares the CPU-only choice against
//! GPU shapes using the [`crate::accel`] speedup model — automating the
//! trial-and-error consulting loop the paper's introduction describes.

use crate::accel::{self, CpuRef, CpuRefSource, GpuSpec};
use crate::coordinator::SweepResult;
use crate::shapes::{self, mset_footprint_bytes, Shape, Workload};
use crate::surface::ResponseSurface;
use crate::util::json::Json;

/// SLA constraints for scoping.
#[derive(Clone, Copy, Debug)]
pub struct Sla {
    /// Required sustained throughput headroom (e.g. 2.0 = run at ≤50% load).
    pub headroom: f64,
    /// Maximum training wall time tolerated (s).
    pub max_train_s: f64,
}

impl Default for Sla {
    fn default() -> Self {
        Sla {
            headroom: 2.0,
            max_train_s: 3600.0,
        }
    }
}

/// One evaluated shape.
#[derive(Clone, Debug)]
pub struct ShapeAssessment {
    /// The catalog shape under assessment.
    pub shape: Shape,
    /// Predicted fraction of the shape consumed by streaming surveillance
    /// (1.0 = saturated).
    pub utilization: f64,
    /// Predicted training wall time on this shape (s).
    pub train_s: f64,
    /// Whether the workload's memory footprint fits.
    pub fits_memory: bool,
    /// Meets all SLA terms.
    pub feasible: bool,
    /// USD per hour.
    pub usd_per_hour: f64,
}

/// Origin of the surface samples behind a recommendation: how many sweep
/// cells were measured to full precision versus accepted at pilot
/// precision by the adaptive planner's surface model. Surfaced (rather
/// than silently merged) so a consumer can tell a fully measured
/// recommendation from a partially interpolated one — and force
/// exhaustive mode (`interpolate=false`, `ci_target=0`) when reproducing
/// the paper figures.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SurfaceBasis {
    /// Cells measured to the planner's CI target (or exhaustively).
    pub measured: usize,
    /// Cells accepted via surface-model interpolation at pilot precision.
    pub interpolated: usize,
    /// Constraint-gap cells (`m < 2n`) with no measurements at all.
    pub gaps: usize,
    /// Cells quarantined after trial-retry exhaustion — excluded from the
    /// surface fits, surfaced so a consumer can tell a clean recommendation
    /// from one computed around poisoned cells.
    pub failed: usize,
}

/// Recommendation output.
#[derive(Clone, Debug)]
pub struct Recommendation {
    /// The customer workload this recommendation is sized for.
    pub workload: Workload,
    /// All shapes, assessed (sorted by price ascending).
    pub assessments: Vec<ShapeAssessment>,
    /// Index of the chosen (cheapest feasible) shape, if any.
    pub chosen: Option<usize>,
    /// Sweep provenance when built by [`recommend_from_sweep`]; `None` for
    /// recommendations built directly from externally fitted surfaces.
    pub basis: Option<SurfaceBasis>,
    /// The calibration the cost figures were computed against (local
    /// testbed throughput plus the CPU reference and its provenance).
    pub calibration: Option<LocalCalibration>,
}

/// Effective throughput of the local testbed implied by the measured
/// surfaces (FLOP/s), used to translate measured seconds to shape seconds
/// — plus the CPU reference the GPU speedup model is quoted against and
/// where that reference came from (paper-anchored analytic constants, or
/// this testbed's measured kernel throughput via
/// [`accel::measured_cpu_ref`]).
#[derive(Clone, Copy, Debug)]
pub struct LocalCalibration {
    /// Effective throughput of the measuring host (FLOP/s).
    pub eff_flops: f64,
    /// CPU reference for the GPU speedup/cost model.
    pub cpu_ref: CpuRef,
    /// Provenance of `cpu_ref`.
    pub cpu_ref_source: CpuRefSource,
}

impl LocalCalibration {
    /// Derive from a surveillance surface: predicted cost of a reference
    /// cell divided into its FLOP count. The CPU reference starts as the
    /// paper-anchored analytic model; [`LocalCalibration::with_measured`]
    /// substitutes a measured one.
    pub fn from_surface(surf: &ResponseSurface, n: usize, m: usize, obs: usize) -> Self {
        let secs = surf.predict(n, m, obs).max(1e-12);
        let flops =
            accel::total_flops(&accel::surveil_routines(n, m, obs, accel::GPU_CHUNK));
        LocalCalibration {
            eff_flops: flops / secs,
            cpu_ref: CpuRef::xeon_platinum(),
            cpu_ref_source: CpuRefSource::PaperAnalytic,
        }
    }

    /// Substitute a CPU reference calibrated from this testbed's measured
    /// kernel throughput (see [`accel::measured_cpu_ref`]).
    pub fn with_measured(mut self, measured: &accel::MeasuredCpu) -> Self {
        self.cpu_ref = measured.cpu;
        self.cpu_ref_source = CpuRefSource::Measured(measured.backend);
        self
    }
}

/// Assess every catalog shape for a workload, using the measured surfaces.
///
/// `train_surface`/`surveil_surface` are the fitted response surfaces from
/// a sweep on the local testbed; costs are rescaled to each shape by the
/// ratio of effective throughputs. GPU shapes apply the V100 speedup model
/// to the dominant kernels.
pub fn recommend(
    workload: &Workload,
    train_surface: &ResponseSurface,
    surveil_surface: &ResponseSurface,
    local: LocalCalibration,
    sla: &Sla,
) -> Recommendation {
    let n = workload.n_signals;
    let m = workload.n_memvec;
    // Measured local costs for this workload.
    let train_local_s = train_surface.predict(n, m, workload.train_window);
    // surveillance cost per single observation (predict at a window, divide)
    let window = 4096;
    let surveil_window_s = surveil_surface.predict(n, m, window);
    let per_obs_local_s = surveil_window_s / window as f64;

    let gpu_spec = GpuSpec::v100();
    let cpu_ref = local.cpu_ref;
    let footprint = mset_footprint_bytes(n, m, 64, workload.train_window);

    let mut assessments: Vec<ShapeAssessment> = shapes::catalog()
        .iter()
        .cloned()
        .map(|shape| {
            let cpu_ratio = local.eff_flops / shape.cpu_eff_flops();
            let (train_s, per_obs_s) = if shape.has_gpu() {
                // GPU path: apply the modelled speedup over the *reference
                // CPU*, expressed relative to this shape's CPU baseline.
                let su_t = accel::speedup_train(n, m, &gpu_spec, &cpu_ref).max(1.0);
                let su_s =
                    accel::speedup_surveil(n, m, window, &gpu_spec, &cpu_ref).max(1.0);
                // reference-CPU times for this workload
                let t_ref_train = accel::total_flops(&accel::train_routines(n, m))
                    / cpu_ref.train_eff_flops;
                let t_ref_obs = accel::total_flops(&accel::surveil_routines(
                    n,
                    m,
                    window,
                    accel::GPU_CHUNK,
                )) / cpu_ref.surveil_eff_flops
                    / window as f64;
                let g = (shape.gpus as f64).max(1.0);
                (t_ref_train / su_t / g, t_ref_obs / su_s / g)
            } else {
                (train_local_s * cpu_ratio, per_obs_local_s * cpu_ratio)
            };
            let demand = workload.obs_per_sec * per_obs_s; // fraction of shape
            let utilization = demand * sla.headroom;
            let fits_memory = (footprint as f64) < shape.mem_gb * 1e9;
            let feasible = utilization < 1.0 && train_s <= sla.max_train_s && fits_memory;
            ShapeAssessment {
                utilization,
                train_s,
                fits_memory,
                feasible,
                usd_per_hour: shape.usd_per_hour,
                shape,
            }
        })
        .collect();

    assessments.sort_by(|a, b| a.usd_per_hour.partial_cmp(&b.usd_per_hour).unwrap());
    let chosen = assessments.iter().position(|a| a.feasible);
    Recommendation {
        workload: *workload,
        assessments,
        chosen,
        basis: None,
        calibration: Some(local),
    }
}

/// The sweep → recommendation pipeline shared by the `scope` subcommand and
/// the service's `GET /v1/recommendations/{id}`: fit both response surfaces
/// from the measured cells, calibrate the local testbed against the
/// largest measured cell, and assess the shape catalog.
///
/// Errors cleanly (no panics) when the sweep axes are empty or the grid is
/// too small to fit a surface.
pub fn recommend_from_sweep(
    result: &SweepResult,
    workload: &Workload,
    sla: &Sla,
) -> anyhow::Result<Recommendation> {
    // Empty-pilot edge case: a grid whose every cell violates the MSET
    // training constraint has nothing to fit — error before the surface
    // fit would report a confusing "need ≥10 samples, got 0".
    let basis = SurfaceBasis {
        measured: result.measured_cells(),
        interpolated: result.interpolated_cells(),
        gaps: result.gap_cells().len(),
        failed: result.failed_cells().len(),
    };
    anyhow::ensure!(
        basis.measured + basis.interpolated > 0,
        "sweep has no measurable cells: all {} grid cells violate the MSET training \
         constraint m ≥ 2n; widen the memvec axis",
        result.cells.len()
    );
    let train_surf = ResponseSurface::fit(&result.samples("train"))?;
    let surveil_surf = ResponseSurface::fit(&result.samples("surveil"))?;
    log::info!(
        "surfaces fitted: train r²={:.4}, surveil r²={:.4}",
        train_surf.r2,
        surveil_surf.r2
    );
    let spec = &result.spec;
    let (ref_n, ref_m, ref_obs) = match (
        spec.signals.last(),
        spec.memvecs.last(),
        spec.obs.last(),
    ) {
        (Some(&n), Some(&m), Some(&obs)) => (n, m, obs),
        _ => anyhow::bail!("sweep axes are empty; cannot calibrate a recommendation"),
    };
    let mut cal = LocalCalibration::from_surface(&surveil_surf, ref_n, ref_m, ref_obs);
    // Honest cost quoting: when the kernel bench has emitted measured
    // per-backend throughput rows for this testbed, anchor the GPU
    // speedup model's CPU term to them instead of the paper-era analytic
    // reference (which stays the documented fallback).
    if let Some(measured) = accel::measured_cpu_ref() {
        log::info!(
            "cpu reference: measured {} calibration from {} (train {:.2} GFLOP/s, surveil {:.2} GFLOP/s)",
            measured.backend,
            measured.path.display(),
            measured.cpu.train_eff_flops / 1e9,
            measured.cpu.surveil_eff_flops / 1e9
        );
        cal = cal.with_measured(&measured);
    }
    let mut rec = recommend(workload, &train_surf, &surveil_surf, cal, sla);
    rec.basis = Some(basis);
    Ok(rec)
}

impl Recommendation {
    /// The cheapest feasible shape's assessment, if any shape is feasible.
    pub fn chosen_shape(&self) -> Option<&ShapeAssessment> {
        self.chosen.map(|i| &self.assessments[i])
    }

    /// JSON rendering (the service's recommendation payload).
    pub fn to_json(&self) -> Json {
        let assessments: Vec<Json> = self
            .assessments
            .iter()
            .map(|a| {
                Json::obj(vec![
                    ("shape", Json::Str(a.shape.name.to_string())),
                    ("usd_per_hour", Json::Num(a.usd_per_hour)),
                    ("train_s", Json::Num(a.train_s)),
                    ("utilization", Json::Num(a.utilization)),
                    ("fits_memory", Json::Bool(a.fits_memory)),
                    ("feasible", Json::Bool(a.feasible)),
                ])
            })
            .collect();
        Json::obj(vec![
            (
                "workload",
                Json::obj(vec![
                    ("signals", Json::Num(self.workload.n_signals as f64)),
                    ("memvecs", Json::Num(self.workload.n_memvec as f64)),
                    ("obs_per_sec", Json::Num(self.workload.obs_per_sec)),
                    (
                        "train_window",
                        Json::Num(self.workload.train_window as f64),
                    ),
                ]),
            ),
            (
                "chosen",
                match self.chosen_shape() {
                    Some(a) => Json::Str(a.shape.name.to_string()),
                    None => Json::Null,
                },
            ),
            (
                "surface_basis",
                match self.basis {
                    Some(b) => Json::obj(vec![
                        ("measured_cells", Json::Num(b.measured as f64)),
                        ("interpolated_cells", Json::Num(b.interpolated as f64)),
                        ("gap_cells", Json::Num(b.gaps as f64)),
                        ("failed_cells", Json::Num(b.failed as f64)),
                    ]),
                    None => Json::Null,
                },
            ),
            (
                "calibration",
                match self.calibration {
                    Some(c) => Json::obj(vec![
                        ("cpu_ref_source", Json::Str(c.cpu_ref_source.label())),
                        ("local_eff_flops", Json::Num(c.eff_flops)),
                        ("cpu_train_eff_flops", Json::Num(c.cpu_ref.train_eff_flops)),
                        (
                            "cpu_surveil_eff_flops",
                            Json::Num(c.cpu_ref.surveil_eff_flops),
                        ),
                    ]),
                    None => Json::Null,
                },
            ),
            ("assessments", Json::Arr(assessments)),
        ])
    }

    /// Render a report table.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "Workload: {} signals, {} memvecs, {:.4} obs/s, train window {}\n",
            self.workload.n_signals,
            self.workload.n_memvec,
            self.workload.obs_per_sec,
            self.workload.train_window
        ));
        if let Some(b) = self.basis {
            out.push_str(&format!(
                "Surfaces: {} measured + {} interpolated cells ({} constraint gaps{})\n",
                b.measured,
                b.interpolated,
                b.gaps,
                if b.failed > 0 {
                    format!(", {} quarantined", b.failed)
                } else {
                    String::new()
                }
            ));
        }
        if let Some(c) = self.calibration {
            out.push_str(&format!(
                "CPU reference: {} (train {:.2} GFLOP/s, surveil {:.2} GFLOP/s); \
                 local testbed {:.2} GFLOP/s\n",
                c.cpu_ref_source.label(),
                c.cpu_ref.train_eff_flops / 1e9,
                c.cpu_ref.surveil_eff_flops / 1e9,
                c.eff_flops / 1e9
            ));
        }
        out.push_str(&format!(
            "{:<18} {:>9} {:>12} {:>10} {:>6} {:>9}\n",
            "shape", "$/hr", "train(s)", "util", "mem", "feasible"
        ));
        for (i, a) in self.assessments.iter().enumerate() {
            let marker = if Some(i) == self.chosen { " ← chosen" } else { "" };
            out.push_str(&format!(
                "{:<18} {:>9.4} {:>12.4} {:>9.1}% {:>6} {:>9}{}\n",
                a.shape.name,
                a.usd_per_hour,
                a.train_s,
                a.utilization * 100.0,
                if a.fits_memory { "ok" } else { "OOM" },
                if a.feasible { "yes" } else { "no" },
                marker
            ));
        }
        out
    }
}

/// One simulated policy's outcome point from the fleet scenario engine
/// ([`crate::scenario::fleet`]): the axes of the cost-vs-violations
/// trade-off the Pareto comparison ranks.
#[derive(Clone, Debug, PartialEq)]
pub struct PolicyPoint {
    /// Policy label (e.g. `reactive(up=0.80 lag=2)`).
    pub label: String,
    /// Fleet total spend (USD).
    pub total_usd: f64,
    /// Tenant-epochs with demand above capacity.
    pub violation_epochs: usize,
    /// Shape migrations performed.
    pub migrations: usize,
}

/// Indices of the Pareto-optimal (non-dominated) policies under
/// (minimise cost, minimise violations): a point is dropped only when
/// another is at most as expensive **and** at most as violating, with at
/// least one strict improvement. Ties survive on both sides.
pub fn pareto_front(points: &[PolicyPoint]) -> Vec<usize> {
    (0..points.len())
        .filter(|&i| {
            !points.iter().enumerate().any(|(j, q)| {
                j != i
                    && q.total_usd <= points[i].total_usd
                    && q.violation_epochs <= points[i].violation_epochs
                    && (q.total_usd < points[i].total_usd
                        || q.violation_epochs < points[i].violation_epochs)
            })
        })
        .collect()
}

/// Choose a policy from its outcome points: the cheapest whose violation
/// count fits `max_violation_epochs`; when none qualifies, the
/// fewest-violations policy (cheapest on ties). `None` only for empty
/// input.
pub fn recommend_policy(points: &[PolicyPoint], max_violation_epochs: usize) -> Option<usize> {
    let within = (0..points.len())
        .filter(|&i| points[i].violation_epochs <= max_violation_epochs)
        .min_by(|&a, &b| points[a].total_usd.total_cmp(&points[b].total_usd));
    within.or_else(|| {
        (0..points.len()).min_by(|&a, &b| {
            points[a]
                .violation_epochs
                .cmp(&points[b].violation_epochs)
                .then(points[a].total_usd.total_cmp(&points[b].total_usd))
        })
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::surface::{ResponseSurface, Sample};

    /// Synthetic surfaces with realistic exponents.
    fn surfaces() -> (ResponseSurface, ResponseSurface, LocalCalibration) {
        let mut train = Vec::new();
        let mut surveil = Vec::new();
        for &n in &[8usize, 16, 32, 64] {
            for &m in &[32usize, 64, 128, 256] {
                for &obs in &[256usize, 1024, 4096] {
                    train.push(Sample {
                        n_signals: n,
                        n_memvec: m,
                        n_obs: obs,
                        cost: 1e-9 * (n as f64) * (m as f64).powi(2),
                    });
                    surveil.push(Sample {
                        n_signals: n,
                        n_memvec: m,
                        n_obs: obs,
                        cost: 2e-10 * (obs as f64) * (m as f64) * (n as f64).sqrt(),
                    });
                }
            }
        }
        let ts = ResponseSurface::fit(&train).unwrap();
        let ss = ResponseSurface::fit(&surveil).unwrap();
        let cal = LocalCalibration::from_surface(&ss, 32, 128, 4096);
        (ts, ss, cal)
    }

    #[test]
    fn small_workload_gets_cheap_shape() {
        let (ts, ss, cal) = surfaces();
        let rec = recommend(&Workload::customer_a(), &ts, &ss, cal, &Sla::default());
        let chosen = rec.chosen_shape().expect("feasible shape exists");
        // Customer A (20 signals @ 1/hr) must not need a bare-metal monster.
        assert!(
            chosen.shape.usd_per_hour <= 0.26,
            "chose {} at ${}",
            chosen.shape.name,
            chosen.shape.usd_per_hour
        );
    }

    #[test]
    fn heavier_stream_needs_bigger_shape() {
        let (ts, ss, cal) = surfaces();
        let light = Workload {
            n_signals: 32,
            n_memvec: 128,
            obs_per_sec: 0.1,
            train_window: 4096,
        };
        let heavy = Workload {
            obs_per_sec: 2000.0,
            ..light
        };
        let r_light = recommend(&light, &ts, &ss, cal, &Sla::default());
        let r_heavy = recommend(&heavy, &ts, &ss, cal, &Sla::default());
        let c_light = r_light.chosen_shape().unwrap().usd_per_hour;
        let c_heavy = r_heavy.chosen_shape().map(|s| s.usd_per_hour);
        if let Some(c_heavy) = c_heavy {
            assert!(c_heavy >= c_light, "heavy {c_heavy} < light {c_light}");
        } // else: infeasible everywhere is acceptable for the heavy case
    }

    #[test]
    fn utilization_monotone_in_rate() {
        let (ts, ss, cal) = surfaces();
        let base = Workload {
            n_signals: 16,
            n_memvec: 64,
            obs_per_sec: 1.0,
            train_window: 1024,
        };
        let fast = Workload {
            obs_per_sec: 100.0,
            ..base
        };
        let r1 = recommend(&base, &ts, &ss, cal, &Sla::default());
        let r2 = recommend(&fast, &ts, &ss, cal, &Sla::default());
        for (a, b) in r1.assessments.iter().zip(&r2.assessments) {
            assert!(b.utilization >= a.utilization);
        }
    }

    #[test]
    fn json_rendering_lists_all_shapes() {
        let (ts, ss, cal) = surfaces();
        let rec = recommend(&Workload::customer_a(), &ts, &ss, cal, &Sla::default());
        let j = rec.to_json();
        assert_eq!(
            j.get("assessments").unwrap().as_arr().unwrap().len(),
            rec.assessments.len()
        );
        assert!(j.get("chosen").unwrap().as_str().is_some());
        // round-trips through the writer/parser
        let j2 = Json::parse(&j.to_string()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn calibration_provenance_is_reported() {
        let (ts, ss, cal) = surfaces();
        assert_eq!(cal.cpu_ref_source, CpuRefSource::PaperAnalytic);
        let rec = recommend(&Workload::customer_a(), &ts, &ss, cal, &Sla::default());
        let j = rec.to_json();
        let c = j.get("calibration").unwrap();
        assert_eq!(
            c.get("cpu_ref_source").unwrap().as_str(),
            Some("paper-analytic")
        );
        assert!(c.get("cpu_train_eff_flops").unwrap().as_f64().unwrap() > 0.0);
        assert!(rec.render().contains("CPU reference: paper-analytic"));

        // substituting a measured CpuRef changes provenance and the rates
        let measured = accel::MeasuredCpu {
            cpu: CpuRef {
                train_eff_flops: 7.5e9,
                surveil_eff_flops: 6.5e9,
            },
            backend: "avx2_fma",
            path: std::path::PathBuf::from("results/BENCH_kernel.json"),
        };
        let cal2 = cal.with_measured(&measured);
        assert_eq!(cal2.cpu_ref_source, CpuRefSource::Measured("avx2_fma"));
        let rec2 = recommend(&Workload::customer_a(), &ts, &ss, cal2, &Sla::default());
        let j2 = rec2.to_json();
        let c2 = j2.get("calibration").unwrap();
        assert_eq!(
            c2.get("cpu_ref_source").unwrap().as_str(),
            Some("measured:avx2_fma")
        );
        assert_eq!(
            c2.get("cpu_train_eff_flops").unwrap().as_f64(),
            Some(7.5e9)
        );
        // the CPU reference cancels in GPU absolute cost (t_ref / speedup):
        // feasibility must not churn when only the quote provenance changes
        for (a, b) in rec.assessments.iter().zip(rec2.assessments.iter()) {
            assert_eq!(a.feasible, b.feasible, "shape {}", a.shape.name);
        }
    }

    #[test]
    fn recommend_from_sweep_pipeline() {
        use crate::coordinator::{run_sweep, Backend, SweepSpec};
        let spec = SweepSpec {
            signals: vec![2, 3],
            memvecs: vec![8, 12, 16],
            obs: vec![16, 32],
            trials: 1,
            seed: 5,
            model: "mset2".into(),
            workers: 2,
            ..SweepSpec::default()
        };
        let result = run_sweep(&spec, Backend::Native).unwrap();
        let rec = recommend_from_sweep(&result, &Workload::customer_a(), &Sla::default())
            .expect("12 measured cells fit a surface");
        assert_eq!(rec.assessments.len(), shapes::catalog().len());
        // exhaustive sweeps report a fully measured basis
        assert_eq!(
            rec.basis,
            Some(SurfaceBasis {
                measured: 12,
                interpolated: 0,
                gaps: 0,
                failed: 0
            })
        );
        assert!(rec.render().contains("12 measured"));
    }

    #[test]
    fn all_gap_sweep_errors_cleanly() {
        use crate::coordinator::{run_sweep, Backend, SweepSpec};
        // Every cell violates m ≥ 2n: the "empty pilot" edge case must be
        // a clean error, not a panic or a confusing fit failure.
        let spec = SweepSpec {
            signals: vec![8, 16],
            memvecs: vec![8],
            obs: vec![16],
            trials: 1,
            seed: 5,
            model: "mset2".into(),
            workers: 1,
            ..SweepSpec::default()
        };
        let result = run_sweep(&spec, Backend::Native).unwrap();
        assert_eq!(result.measured_cells(), 0);
        let err = recommend_from_sweep(&result, &Workload::customer_a(), &Sla::default())
            .unwrap_err()
            .to_string();
        assert!(err.contains("no measurable cells"), "{err}");
    }

    fn pt(label: &str, usd: f64, viol: usize) -> PolicyPoint {
        PolicyPoint {
            label: label.into(),
            total_usd: usd,
            violation_epochs: viol,
            migrations: 0,
        }
    }

    #[test]
    fn pareto_front_keeps_non_dominated_points() {
        let points = vec![
            pt("prescoped", 1000.0, 0),  // dominated by predictive
            pt("reactive", 400.0, 12),   // cheapest
            pt("predictive", 600.0, 0),  // zero violations, mid cost
            pt("worst", 1200.0, 20),     // dominated by everything
        ];
        let front = pareto_front(&points);
        assert_eq!(front, vec![1, 2]);
        // duplicates both survive (neither strictly dominates the other)
        let twins = vec![pt("a", 5.0, 1), pt("b", 5.0, 1)];
        assert_eq!(pareto_front(&twins), vec![0, 1]);
        assert!(pareto_front(&[]).is_empty());
    }

    #[test]
    fn recommend_policy_prefers_budget_then_fewest_violations() {
        let points = vec![
            pt("prescoped", 1000.0, 0),
            pt("reactive", 400.0, 12),
            pt("predictive", 600.0, 0),
        ];
        // zero-violation budget: cheapest clean policy wins
        assert_eq!(recommend_policy(&points, 0), Some(2));
        // a loose budget admits the cheap reactive policy
        assert_eq!(recommend_policy(&points, 20), Some(1));
        // nothing fits: fall back to fewest violations, cheaper tie
        let dirty = vec![pt("a", 900.0, 5), pt("b", 700.0, 5), pt("c", 100.0, 9)];
        assert_eq!(recommend_policy(&dirty, 0), Some(1));
        assert_eq!(recommend_policy(&[], 0), None);
    }

    #[test]
    fn render_mentions_chosen() {
        let (ts, ss, cal) = surfaces();
        let rec = recommend(&Workload::customer_a(), &ts, &ss, cal, &Sla::default());
        let text = rec.render();
        assert!(text.contains("chosen"));
        assert!(text.contains("VM.Standard2.1"));
    }
}
