//! PJRT execution engine (device-thread confined).
//!
//! Wraps the `xla` crate: `PjRtClient::cpu()` → `HloModuleProto::
//! from_text_file` → `compile` → `execute`. Executables are compiled
//! lazily on first use and cached for the life of the engine — compile
//! time is reported separately from execute time so the Monte Carlo cost
//! measurements never include compilation.
//!
//! `PjRtClient` is `Rc`-based (not `Send`), so `Engine` must stay on one
//! thread; [`super::DeviceServer`] provides the thread-safe front door.

use super::manifest::{ArtifactMeta, Manifest};
use std::collections::HashMap;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A host-side tensor (f32, row-major) that can cross thread boundaries.
///
/// The element buffer is behind an `Arc`, so `clone` is O(1): sessions
/// resubmit the same padded `D`/mask/bandwidth tensors on every
/// `train()`/`bind` call, and those used to deep-copy ~1 MB of padding
/// at the largest bucket each time.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    /// Dimensions (row-major).
    pub shape: Vec<usize>,
    /// Flat row-major element buffer (shared; cheap to clone).
    pub data: Arc<Vec<f32>>,
}

impl Tensor {
    /// Tensor from a shape and a matching flat buffer.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data");
        Tensor {
            shape,
            data: Arc::new(data),
        }
    }

    /// Rank-1 single-element tensor (scalar inputs to HLO programs).
    pub fn scalar1(v: f32) -> Tensor {
        Tensor {
            shape: vec![1],
            data: Arc::new(vec![v]),
        }
    }
}

/// Result of one device execution.
#[derive(Clone, Debug)]
pub struct ExecResult {
    /// Program outputs, in manifest order.
    pub outputs: Vec<Tensor>,
    /// Pure execute wall time (excludes compile).
    pub exec_time: Duration,
    /// Compile time if this call triggered the first compilation.
    pub compiled_in: Option<Duration>,
}

/// Device-thread-confined engine.
pub struct Engine {
    /// The artifact manifest the engine was loaded from.
    pub manifest: Manifest,
    client: xla::PjRtClient,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
    /// Bound sessions: **device-resident** input prefixes (§Perf — the
    /// streaming path keeps D/G/mask/bw as PjRtBuffers across chunks;
    /// plain `execute` would re-upload ~1.3 MB of literals per call).
    sessions: HashMap<u64, BoundSession>,
}

struct BoundSession {
    artifact_id: String,
    prefix: Vec<xla::PjRtBuffer>,
}

fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let lit = xla::Literal::vec1(t.data.as_slice());
    let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
    lit.reshape(&dims)
        .map_err(|e| anyhow::anyhow!("reshape input: {e}"))
}

impl Engine {
    /// Create a CPU PJRT engine over an artifact directory.
    pub fn load(artifact_dir: impl AsRef<std::path::Path>) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT CPU client: {e}"))?;
        log::info!(
            "PJRT engine up: platform={} devices={} artifacts={} (profile {})",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len(),
            manifest.profile,
        );
        Ok(Engine {
            manifest,
            client,
            cache: HashMap::new(),
            sessions: HashMap::new(),
        })
    }

    /// Number of executables compiled so far (cache size).
    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }

    fn compile_if_needed(&mut self, id: &str) -> anyhow::Result<Option<Duration>> {
        if self.cache.contains_key(id) {
            return Ok(None);
        }
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.id == id)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{id}'"))?
            .clone();
        let path = self.manifest.hlo_path(&art);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {id}: {e}"))?;
        let dt = t0.elapsed();
        log::debug!("compiled {id} in {:.3}s", dt.as_secs_f64());
        self.cache.insert(id.to_string(), exe);
        Ok(Some(dt))
    }

    /// Execute an artifact with the given inputs (validated against the
    /// manifest). Outputs are unpacked from the return tuple in manifest
    /// order.
    pub fn exec(&mut self, id: &str, inputs: &[Tensor]) -> anyhow::Result<ExecResult> {
        let compiled_in = self.compile_if_needed(id)?;
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.id == id)
            .unwrap()
            .clone();
        validate_inputs(&art, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(tensor_to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        let refs: Vec<&xla::Literal> = literals.iter().collect();
        self.run_literals(id, &art, &refs, compiled_in)
    }

    /// Bind an input prefix for repeated execution: marshals the first
    /// `prefix.len()` manifest inputs of `id` into device literals once.
    pub fn bind(&mut self, session: u64, id: &str, prefix: &[Tensor]) -> anyhow::Result<()> {
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.id == id)
            .ok_or_else(|| anyhow::anyhow!("unknown artifact '{id}'"))?;
        anyhow::ensure!(
            prefix.len() <= art.inputs.len(),
            "prefix longer than artifact inputs"
        );
        for (t, spec) in prefix.iter().zip(&art.inputs) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "bind {id}: input '{}' shape {:?} != manifest {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
        }
        let buffers = prefix
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(t.data.as_slice(), &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload bound input: {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        self.sessions.insert(
            session,
            BoundSession {
                artifact_id: id.to_string(),
                prefix: buffers,
            },
        );
        Ok(())
    }

    /// Execute a bound session with the remaining (tail) inputs.
    pub fn exec_bound(&mut self, session: u64, tail: &[Tensor]) -> anyhow::Result<ExecResult> {
        let id = self
            .sessions
            .get(&session)
            .ok_or_else(|| anyhow::anyhow!("unknown session {session}"))?
            .artifact_id
            .clone();
        let compiled_in = self.compile_if_needed(&id)?;
        let art = self
            .manifest
            .artifacts
            .iter()
            .find(|a| a.id == id)
            .unwrap()
            .clone();
        let sess = self.sessions.get(&session).unwrap();
        anyhow::ensure!(
            sess.prefix.len() + tail.len() == art.inputs.len(),
            "session {session}: {} bound + {} tail != {} inputs",
            sess.prefix.len(),
            tail.len(),
            art.inputs.len()
        );
        for (t, spec) in tail.iter().zip(&art.inputs[sess.prefix.len()..]) {
            anyhow::ensure!(
                t.shape == spec.shape,
                "exec_bound {id}: input '{}' shape {:?} != manifest {:?}",
                spec.name,
                t.shape,
                spec.shape
            );
        }
        let tail_bufs = tail
            .iter()
            .map(|t| {
                self.client
                    .buffer_from_host_buffer::<f32>(t.data.as_slice(), &t.shape, None)
                    .map_err(|e| anyhow::anyhow!("upload tail input: {e}"))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        let sess = self.sessions.get(&session).unwrap();
        let refs: Vec<&xla::PjRtBuffer> = sess.prefix.iter().chain(tail_bufs.iter()).collect();
        self.run_buffers(&id, &art, &refs, compiled_in)
    }

    /// Drop a bound session (frees its literals).
    pub fn unbind(&mut self, session: u64) {
        self.sessions.remove(&session);
    }

    fn run_literals(
        &self,
        id: &str,
        art: &ArtifactMeta,
        literals: &[&xla::Literal],
        compiled_in: Option<Duration>,
    ) -> anyhow::Result<ExecResult> {
        let exe = self.cache.get(id).unwrap();
        let t0 = Instant::now();
        let result = exe
            .execute::<&xla::Literal>(literals)
            .map_err(|e| anyhow::anyhow!("execute {id}: {e}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        let exec_time = t0.elapsed();
        self.unpack_outputs(id, art, out_lit, exec_time, compiled_in)
    }

    /// Buffer-path execution (bound sessions): inputs already live on the
    /// device, so only the tail upload and the output download move data.
    fn run_buffers(
        &self,
        id: &str,
        art: &ArtifactMeta,
        buffers: &[&xla::PjRtBuffer],
        compiled_in: Option<Duration>,
    ) -> anyhow::Result<ExecResult> {
        let exe = self.cache.get(id).unwrap();
        let t0 = Instant::now();
        let result = exe
            .execute_b::<&xla::PjRtBuffer>(buffers)
            .map_err(|e| anyhow::anyhow!("execute_b {id}: {e}"))?;
        let out_lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("fetch result: {e}"))?;
        let exec_time = t0.elapsed();
        self.unpack_outputs(id, art, out_lit, exec_time, compiled_in)
    }

    fn unpack_outputs(
        &self,
        id: &str,
        art: &ArtifactMeta,
        out_lit: xla::Literal,
        exec_time: Duration,
        compiled_in: Option<Duration>,
    ) -> anyhow::Result<ExecResult> {

        // Graphs are lowered with return_tuple=True.
        let parts = out_lit
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple: {e}"))?;
        anyhow::ensure!(
            parts.len() == art.outputs.len(),
            "artifact {id}: expected {} outputs, got {}",
            art.outputs.len(),
            parts.len()
        );
        let outputs = parts
            .into_iter()
            .zip(&art.outputs)
            .map(|(lit, spec)| {
                let data = lit
                    .to_vec::<f32>()
                    .map_err(|e| anyhow::anyhow!("output {}: {e}", spec.name))?;
                anyhow::ensure!(
                    data.len() == spec.shape.iter().product::<usize>(),
                    "output {} size mismatch",
                    spec.name
                );
                Ok(Tensor::new(spec.shape.clone(), data))
            })
            .collect::<anyhow::Result<Vec<_>>>()?;
        Ok(ExecResult {
            outputs,
            exec_time,
            compiled_in,
        })
    }
}

fn validate_inputs(art: &ArtifactMeta, inputs: &[Tensor]) -> anyhow::Result<()> {
    anyhow::ensure!(
        inputs.len() == art.inputs.len(),
        "artifact {}: expected {} inputs, got {}",
        art.id,
        art.inputs.len(),
        inputs.len()
    );
    for (t, spec) in inputs.iter().zip(&art.inputs) {
        anyhow::ensure!(
            t.shape == spec.shape,
            "artifact {}: input '{}' shape {:?} != manifest {:?}",
            art.id,
            spec.name,
            t.shape,
            spec.shape
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::TEST_MANIFEST;
    use std::path::PathBuf;

    #[test]
    fn tensor_shape_checked() {
        let t = Tensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.shape, vec![2, 3]);
    }

    #[test]
    #[should_panic(expected = "shape/data")]
    fn tensor_bad_shape_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn validate_inputs_catches_mismatch() {
        let man = Manifest::parse(TEST_MANIFEST, PathBuf::from(".")).unwrap();
        let art = man.find("mset2_train", 8, 32).unwrap();
        let good = vec![
            Tensor::new(vec![32, 8], vec![0.0; 256]),
            Tensor::new(vec![32], vec![1.0; 32]),
            Tensor::scalar1(1.4),
        ];
        assert!(validate_inputs(art, &good).is_ok());
        let bad = vec![
            Tensor::new(vec![32, 8], vec![0.0; 256]),
            Tensor::new(vec![16], vec![1.0; 16]),
            Tensor::scalar1(1.4),
        ];
        assert!(validate_inputs(art, &bad).is_err());
        assert!(validate_inputs(art, &good[..2].to_vec()).is_err());
    }
}
