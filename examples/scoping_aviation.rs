//! Scenario: **commercial-aviation fleet telemetry** — the paper's
//! "Customer B" extreme (§I): an Airbus A320 fleet with 75 000 sensors per
//! plane at 1 Hz (20 TB/month/plane). Sensors are partitioned into
//! 1024-signal prognostic groups; this example scopes one partition:
//!
//! 1. measures cost growth on the device across the scaled grid,
//! 2. extrapolates to the partition size via the response surface,
//! 3. compares CPU-only shapes with V100 shapes through the accel model —
//!    reproducing the paper's conclusion that big-data use cases want GPUs.
//!
//! Run: `make artifacts && cargo run --release --example scoping_aviation`

use containerstress::accel::{self, CpuRef, GpuSpec};
use containerstress::coordinator::{run_sweep, Backend, SweepSpec};
use containerstress::recommend::{recommend, LocalCalibration, Sla};
use containerstress::runtime::DeviceServer;
use containerstress::shapes::Workload;
use containerstress::surface::ResponseSurface;

fn main() -> anyhow::Result<()> {
    containerstress::util::logger::init();
    let server = DeviceServer::start(containerstress::runtime::default_artifact_dir())?;

    // Device sweep on the scaled grid (the surface extrapolates beyond it).
    let spec = SweepSpec {
        signals: vec![4, 8, 16],
        memvecs: vec![32, 48, 64],
        obs: vec![64, 256, 1024],
        trials: 3,
        seed: 320,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    };
    let result = run_sweep(&spec, Backend::Device(server.handle()))?;
    // Customer B sits far outside the measured grid: use the power-law fit,
    // which extrapolates safely (the quadratic's curvature does not).
    let train_surf = ResponseSurface::fit_power_law(&result.samples("train"))?;
    let surveil_surf = ResponseSurface::fit_power_law(&result.samples("surveil"))?;

    // One A320 partition: 1024 signals at 1 Hz.
    let workload = Workload::customer_b_partition();
    println!(
        "A320 partition: {} signals, {} memvecs, {} obs/s",
        workload.n_signals, workload.n_memvec, workload.obs_per_sec
    );
    let pred_train = train_surf.predict(workload.n_signals, workload.n_memvec, workload.train_window);
    let pred_obs =
        surveil_surf.predict(workload.n_signals, workload.n_memvec, 3600) / 3600.0;
    println!(
        "surface extrapolation (local testbed): training ≈ {:.1} s, {:.2} ms/obs streaming",
        pred_train,
        pred_obs * 1e3
    );

    // GPU vs CPU for this partition (the paper's Figs. 6–8 question).
    let gpu = GpuSpec::v100();
    let cpu = CpuRef::xeon_platinum();
    let su_train = accel::speedup_train(workload.n_signals, workload.n_memvec, &gpu, &cpu);
    let su_surveil = accel::speedup_surveil(
        workload.n_signals,
        workload.n_memvec,
        1 << 20,
        &gpu,
        &cpu,
    );
    println!(
        "modelled V100 speedup: training {su_train:.0}×, sustained surveillance {su_surveil:.0}×"
    );

    let cal = LocalCalibration::from_surface(&surveil_surf, 16, 64, 1024);
    let rec = recommend(
        &workload,
        &train_surf,
        &surveil_surf,
        cal,
        &Sla {
            headroom: 2.0,
            max_train_s: 7200.0,
        },
    );
    println!("\n{}", rec.render());
    match rec.chosen_shape() {
        Some(c) => println!("→ scope: {} at ${:.2}/hr", c.shape.name, c.usd_per_hour),
        None => println!("→ no single shape sustains this partition; shard further"),
    }
    Ok(())
}
