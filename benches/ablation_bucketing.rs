//! **ABL-2**: bucket-padding overhead.
//!
//! The runtime zero-pads workloads up to the nearest artifact bucket
//! (DESIGN.md §2.3). This bench measures the cost of that padding by
//! comparing workloads that exactly fill a bucket against workloads just
//! past the previous bucket boundary (worst-case padding waste), for both
//! the signal and the memory dimension.
//!
//! Output: `results/ablation_bucketing.csv`.

use containerstress::bench::{figs, table, write_csv, Bencher};
use containerstress::linalg::Mat;
use containerstress::util::rng::Rng;

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut m = Mat::zeros(rows, cols);
    rng.fill_gauss(&mut m.data);
    m
}

fn main() {
    containerstress::util::logger::init();
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (sigs, mems) = figs::available_axes(&handle);
    if sigs.len() < 2 || mems.len() < 2 {
        eprintln!("need ≥2 buckets per axis; run `make artifacts ARTIFACT_PROFILE=full`");
        return;
    }
    let b = if figs::quick() {
        Bencher::quick()
    } else {
        Bencher::default()
    };
    let obs = 512;
    let mut ms = Vec::new();

    // --- signal-dimension padding ------------------------------------------
    let n_lo = sigs[sigs.len() - 2];
    let n_hi = *sigs.last().unwrap();
    let m_fix = *mems.last().unwrap();
    for (label, n) in [("n_exact", n_hi), ("n_worstpad", n_lo + 1)] {
        let mut sess = figs::session_for(&handle, n, m_fix, 7);
        sess.train().expect("train");
        let probe = random_mat(obs, n, 8);
        ms.push(b.run_with_units(&format!("{label}_{n}→bucket{}", sess.bucket.n), obs as f64, || {
            sess.surveil(&probe).expect("surveil")
        }));
    }

    // --- memory-dimension padding ------------------------------------------
    let m_lo = mems[mems.len() - 2];
    let m_hi = *mems.last().unwrap();
    let n_fix = sigs[0];
    for (label, m) in [("m_exact", m_hi), ("m_worstpad", m_lo + 1)] {
        let mut sess = figs::session_for(&handle, n_fix, m, 9);
        sess.train().expect("train");
        let probe = random_mat(obs, n_fix, 10);
        ms.push(b.run_with_units(
            &format!("{label}_{m}→bucket{}", sess.bucket.m),
            obs as f64,
            || sess.surveil(&probe).expect("surveil"),
        ));
    }

    println!("{}", table(&ms));
    // Padding overhead summary: worst-pad runs execute at bucket size, so
    // their cost should match the exact-fill runs (same executable), and
    // the "overhead" is the bucket-vs-real work ratio, not extra latency.
    let exact = ms[0].stats.median;
    let padded = ms[1].stats.median;
    println!(
        "signal-dim worst-case padding: {:.1}% latency delta at equal bucket",
        (padded / exact - 1.0) * 100.0
    );
    write_csv("results/ablation_bucketing.csv", &ms).unwrap();
    println!("ablation_bucketing done → results/ablation_bucketing.csv");
}
