//! Deterministic pseudo-random number generation.
//!
//! The offline build environment has no `rand` crate, so ContainerStress
//! ships its own generator. We use **PCG64** (permuted congruential
//! generator, XSL-RR 128/64 output function) — the same family the `rand`
//! crate uses for `StdRng`-class work — seeded through SplitMix64 so that
//! small integer seeds expand to well-distributed state.
//!
//! Every Monte Carlo trial in the sweep engine derives its own stream via
//! [`Rng::fork`], which keeps trials independent of scheduling order (a
//! coordinator invariant tested in `rust/tests/coordinator_props.rs`).

/// PCG64 XSL-RR 128/64.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u128,
    inc: u128,
    /// Cached second output of the Box–Muller transform.
    gauss_spare: Option<f64>,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9e37_79b9_7f4a_7c15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a small seed. Distinct seeds give distinct,
    /// uncorrelated streams (seeded through SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let hi = splitmix64(&mut sm) as u128;
        let lo = splitmix64(&mut sm) as u128;
        let inc_hi = splitmix64(&mut sm) as u128;
        let inc_lo = splitmix64(&mut sm) as u128;
        let mut rng = Rng {
            state: (hi << 64) | lo,
            // stream selector must be odd
            inc: ((inc_hi << 64) | inc_lo) | 1,
            gauss_spare: None,
        };
        // advance once so that state reflects the increment
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream; used to give each Monte Carlo
    /// trial its own generator regardless of worker scheduling.
    pub fn fork(&self, tag: u64) -> Rng {
        // Mix the tag into fresh seed material drawn deterministically from
        // the parent's *current* state without disturbing it.
        let mut sm = (self.state >> 64) as u64 ^ (self.state as u64) ^ tag.wrapping_mul(0x9e37_79b9_7f4a_7c15);
        let s = splitmix64(&mut sm);
        Rng::new(s ^ tag.rotate_left(17))
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's unbiased method).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range_usize(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn gauss(&mut self) -> f64 {
        if let Some(v) = self.gauss_spare.take() {
            return v;
        }
        // Avoid u1 == 0 exactly.
        let u1 = loop {
            let u = self.f64();
            if u > 1e-300 {
                break u;
            }
        };
        let u2 = self.f64();
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.gauss_spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Normal with the given mean and standard deviation.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.gauss()
    }

    /// Fill a slice with standard normal draws.
    pub fn fill_gauss(&mut self, out: &mut [f64]) {
        for v in out.iter_mut() {
            *v = self.gauss();
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range_usize(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Exponential with the given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn distinct_seeds_distinct_streams() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn uniform_mean_and_range() {
        let mut rng = Rng::new(7);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 7];
        let n = 70_000;
        for _ in 0..n {
            counts[rng.below(7) as usize] += 1;
        }
        for &c in &counts {
            let expect = n / 7;
            assert!((c as f64 - expect as f64).abs() < 0.05 * expect as f64);
        }
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Rng::new(11);
        let n = 200_000;
        let (mut s1, mut s2, mut s3, mut s4) = (0.0, 0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.gauss();
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
            s4 += x * x * x * x;
        }
        let nf = n as f64;
        let mean = s1 / nf;
        let var = s2 / nf - mean * mean;
        let skew = (s3 / nf - 3.0 * mean * var - mean.powi(3)) / var.powf(1.5);
        let kurt = s4 / nf / (var * var);
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
        assert!(skew.abs() < 0.05, "skew={skew}");
        assert!((kurt - 3.0).abs() < 0.1, "kurt={kurt}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::new(5);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
        // fork is deterministic
        let mut a2 = root.fork(0);
        let mut a3 = root.fork(0);
        for _ in 0..16 {
            assert_eq!(a2.next_u64(), a3.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(9);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(13);
        let idx = rng.sample_indices(50, 20);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
        assert!(idx.iter().all(|&i| i < 50));
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Rng::new(21);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            sum += rng.exponential(2.0);
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }
}
