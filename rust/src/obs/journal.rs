//! Durable telemetry journal: append-only, size-rotated NDJSON files of
//! retired spans and periodic metric/SLO snapshots.
//!
//! The journal is the crash-surviving half of the ops plane: per-job
//! flight-recorder rings and the metrics registry die with the process,
//! but every record appended here can be re-read after a restart (or on
//! another machine) by `containerstress obs` and the tests.
//!
//! **Format.** One compact JSON object per line. Every record is
//! self-describing via its `kind` field (`"span"`, `"metrics"`, `"slo"`)
//! and carries a wall-clock `ts_ms`. Files are named
//! `telemetry.<seq>.ndjson` with a monotone sequence number; rotation
//! starts a new file once the active one exceeds `max_file_bytes`, and
//! the oldest files are deleted to keep the directory under
//! `max_total_bytes` — disk use is bounded by configuration, never by
//! uptime.
//!
//! **Crash tolerance.** A crash mid-write leaves a torn tail: a partial
//! last line, or a complete line of garbage. [`Journal::open`] recovers
//! by truncating trailing bytes until the last line parses as JSON, then
//! resumes appending — readers never see the torn record, and the intact
//! prefix is preserved byte-for-byte.
//!
//! **Durability knob.** `fsync` selects how eagerly the OS is asked to
//! persist: [`FsyncPolicy::Never`] (buffered writes only, cheapest),
//! [`FsyncPolicy::Rotate`] (fsync when sealing a file — at most one
//! file's worth of records at risk), [`FsyncPolicy::Always`] (fsync per
//! append — every acknowledged record survives power loss).

use crate::util::json::Json;
use std::fs::{self, File, OpenOptions};
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Default per-file rotation threshold (8 MiB).
pub const DEFAULT_MAX_FILE_BYTES: u64 = 8 << 20;

/// Default whole-directory disk cap (64 MiB).
pub const DEFAULT_MAX_TOTAL_BYTES: u64 = 64 << 20;

/// Default journal file prefix (the telemetry journal's). Other journal
/// users (the job WAL) pick their own prefix so several journals can
/// coexist without clashing sequence files.
pub const DEFAULT_FILE_PREFIX: &str = "telemetry.";
const FILE_SUFFIX: &str = ".ndjson";

/// How eagerly journal writes are flushed to stable storage.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum FsyncPolicy {
    /// Buffered writes only; the OS flushes at its leisure. Cheapest —
    /// the obs-overhead bench gate runs with this policy.
    #[default]
    Never,
    /// `fsync` when a file is sealed at rotation: at most one active
    /// file's worth of records is at risk on power loss.
    Rotate,
    /// `fsync` after every append: every acknowledged record is durable.
    Always,
}

impl FsyncPolicy {
    /// Parse the config/CLI spelling (`never` | `rotate` | `always`).
    pub fn parse(s: &str) -> anyhow::Result<FsyncPolicy> {
        match s {
            "never" => Ok(FsyncPolicy::Never),
            "rotate" => Ok(FsyncPolicy::Rotate),
            "always" => Ok(FsyncPolicy::Always),
            other => anyhow::bail!("unknown fsync policy {other:?} (never|rotate|always)"),
        }
    }

    /// Canonical spelling for config round-trips.
    pub fn as_str(&self) -> &'static str {
        match self {
            FsyncPolicy::Never => "never",
            FsyncPolicy::Rotate => "rotate",
            FsyncPolicy::Always => "always",
        }
    }
}

/// Journal location and bounds.
#[derive(Clone, Debug)]
pub struct JournalConfig {
    /// Directory holding the `telemetry.<seq>.ndjson` files (created on
    /// open).
    pub dir: PathBuf,
    /// Rotation threshold: a file exceeding this is sealed and a new
    /// sequence number started.
    pub max_file_bytes: u64,
    /// Whole-directory cap: oldest sealed files are deleted to stay
    /// under it.
    pub max_total_bytes: u64,
    /// Durability policy.
    pub fsync: FsyncPolicy,
    /// File-name prefix (`<prefix><seq>.ndjson`); defaults to
    /// [`DEFAULT_FILE_PREFIX`]. Distinct prefixes let independent
    /// journals (telemetry, the job WAL) share rotation machinery.
    pub file_prefix: String,
}

impl JournalConfig {
    /// Config with default bounds, [`FsyncPolicy::Never`], and the
    /// telemetry file prefix.
    pub fn new(dir: impl Into<PathBuf>) -> JournalConfig {
        JournalConfig {
            dir: dir.into(),
            max_file_bytes: DEFAULT_MAX_FILE_BYTES,
            max_total_bytes: DEFAULT_MAX_TOTAL_BYTES,
            fsync: FsyncPolicy::Never,
            file_prefix: DEFAULT_FILE_PREFIX.to_string(),
        }
    }
}

struct Writer {
    file: BufWriter<File>,
    /// Bytes in the active file (including the recovered prefix).
    written: u64,
    seq: u64,
}

/// Append-only, size-rotated NDJSON telemetry journal (see the module
/// docs for format, rotation, and recovery semantics).
pub struct Journal {
    cfg: JournalConfig,
    inner: Mutex<Writer>,
    appended: AtomicU64,
    errors: AtomicU64,
}

impl Journal {
    /// Open (or create) the journal in `cfg.dir`, recovering any torn
    /// tail left by a crash and resuming the highest existing sequence
    /// number.
    pub fn open(cfg: JournalConfig) -> anyhow::Result<Journal> {
        anyhow::ensure!(cfg.max_file_bytes >= 1024, "journal max_file_bytes must be >= 1024");
        anyhow::ensure!(
            cfg.max_total_bytes >= cfg.max_file_bytes,
            "journal max_total_bytes must be >= max_file_bytes"
        );
        fs::create_dir_all(&cfg.dir)?;
        let files = list_files_with_prefix(&cfg.dir, &cfg.file_prefix)?;
        let (seq, written) = match files.last() {
            None => (1, 0),
            Some((seq, path)) => {
                let valid = recover_torn_tail(path)?;
                (*seq, valid)
            }
        };
        let path = file_path(&cfg.dir, &cfg.file_prefix, seq);
        let file = OpenOptions::new().create(true).append(true).open(&path)?;
        let journal = Journal {
            cfg,
            inner: Mutex::new(Writer {
                file: BufWriter::new(file),
                written,
                seq,
            }),
            appended: AtomicU64::new(0),
            errors: AtomicU64::new(0),
        };
        journal.enforce_total_cap();
        Ok(journal)
    }

    /// Directory the journal writes into.
    pub fn dir(&self) -> &Path {
        &self.cfg.dir
    }

    /// Records successfully appended since open.
    pub fn appended(&self) -> u64 {
        self.appended.load(Ordering::Relaxed)
    }

    /// Append errors since open (each is also logged; appends never
    /// panic the caller — telemetry must not take the service down).
    pub fn errors(&self) -> u64 {
        self.errors.load(Ordering::Relaxed)
    }

    /// Append one record as a compact NDJSON line. Errors are counted
    /// and logged, never propagated: span retirement happens on executor
    /// hot paths that must not fail because a disk did.
    pub fn append(&self, frame: &Json) {
        let mut line = frame.to_string();
        line.push('\n');
        let mut w = self.inner.lock().unwrap();
        // Chaos hook; the tag advances with both counters so a failed
        // injection doesn't pin the same decision forever.
        let tag = self
            .appended
            .load(Ordering::Relaxed)
            .wrapping_add(self.errors.load(Ordering::Relaxed));
        let injected = crate::util::failpoint::hit_no_panic("journal.append", tag);
        if let Err(e) = injected
            .and_then(|_| self.append_locked(&mut w, line.as_bytes()).map_err(Into::into))
        {
            drop(w);
            if self.errors.fetch_add(1, Ordering::Relaxed) == 0 {
                log::warn!("telemetry journal append failed (further errors counted): {e}");
            }
        } else {
            self.appended.fetch_add(1, Ordering::Relaxed);
        }
    }

    fn append_locked(&self, w: &mut Writer, line: &[u8]) -> std::io::Result<()> {
        w.file.write_all(line)?;
        w.written += line.len() as u64;
        if self.cfg.fsync == FsyncPolicy::Always {
            w.file.flush()?;
            w.file.get_ref().sync_data()?;
        }
        if w.written >= self.cfg.max_file_bytes {
            self.rotate_locked(w)?;
        }
        Ok(())
    }

    fn rotate_locked(&self, w: &mut Writer) -> std::io::Result<()> {
        w.file.flush()?;
        if self.cfg.fsync != FsyncPolicy::Never {
            w.file.get_ref().sync_data()?;
        }
        w.seq += 1;
        let path = file_path(&self.cfg.dir, &self.cfg.file_prefix, w.seq);
        let file = OpenOptions::new().create(true).append(true).open(path)?;
        w.file = BufWriter::new(file);
        w.written = 0;
        self.enforce_total_cap();
        Ok(())
    }

    /// Delete the oldest sealed files until the directory fits the total
    /// cap; the active file is never deleted.
    fn enforce_total_cap(&self) {
        let Ok(files) = list_files_with_prefix(&self.cfg.dir, &self.cfg.file_prefix) else {
            return;
        };
        let sizes: Vec<(u64, PathBuf, u64)> = files
            .into_iter()
            .map(|(seq, p)| {
                let len = fs::metadata(&p).map(|m| m.len()).unwrap_or(0);
                (seq, p, len)
            })
            .collect();
        let mut total: u64 = sizes.iter().map(|(_, _, len)| len).sum();
        for (i, (_, path, len)) in sizes.iter().enumerate() {
            // keep at least the newest (active) file
            if total <= self.cfg.max_total_bytes || i + 1 == sizes.len() {
                break;
            }
            if fs::remove_file(path).is_ok() {
                total = total.saturating_sub(*len);
            }
        }
    }

    /// Flush buffered records to the OS (called at service shutdown and
    /// by [`Drop`]).
    pub fn flush(&self) {
        let mut w = self.inner.lock().unwrap();
        let _ = w.file.flush();
        if self.cfg.fsync != FsyncPolicy::Never {
            let _ = w.file.get_ref().sync_data();
        }
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        self.flush();
    }
}

fn file_path(dir: &Path, prefix: &str, seq: u64) -> PathBuf {
    dir.join(format!("{prefix}{seq:08}{FILE_SUFFIX}"))
}

/// Telemetry journal files in `dir`, sorted by ascending sequence number.
pub fn list_files(dir: &Path) -> anyhow::Result<Vec<(u64, PathBuf)>> {
    list_files_with_prefix(dir, DEFAULT_FILE_PREFIX)
}

/// Journal files named `<prefix><seq>.ndjson` in `dir`, sorted by
/// ascending sequence number.
pub fn list_files_with_prefix(
    dir: &Path,
    prefix: &str,
) -> anyhow::Result<Vec<(u64, PathBuf)>> {
    let mut files = Vec::new();
    let entries = match fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(files),
        Err(e) => return Err(e.into()),
    };
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        let Some(seq) = name
            .strip_prefix(prefix)
            .and_then(|s| s.strip_suffix(FILE_SUFFIX))
            .and_then(|s| s.parse::<u64>().ok())
        else {
            continue;
        };
        files.push((seq, entry.path()));
    }
    files.sort_unstable_by_key(|(seq, _)| *seq);
    Ok(files)
}

/// Read every telemetry record across the journal's files in append
/// order, tolerating a torn tail (trailing unparseable lines of the
/// newest file are skipped, mirroring what [`Journal::open`] would
/// truncate).
pub fn read_records(dir: &Path) -> anyhow::Result<Vec<Json>> {
    read_records_with_prefix(dir, DEFAULT_FILE_PREFIX)
}

/// [`read_records`] for a journal with a custom file prefix.
pub fn read_records_with_prefix(dir: &Path, prefix: &str) -> anyhow::Result<Vec<Json>> {
    let mut out = Vec::new();
    for (_, path) in list_files_with_prefix(dir, prefix)? {
        let text = fs::read_to_string(&path)?;
        for line in text.lines() {
            if line.is_empty() {
                continue;
            }
            if let Ok(j) = Json::parse(line) {
                out.push(j);
            }
        }
    }
    Ok(out)
}

/// Truncate `path` to its longest prefix of whole, parseable NDJSON
/// lines and return that length. A file ending cleanly is untouched.
fn recover_torn_tail(path: &Path) -> anyhow::Result<u64> {
    let bytes = fs::read(path)?;
    let valid = valid_prefix_len(&bytes);
    if valid < bytes.len() as u64 {
        log::warn!(
            "telemetry journal {}: recovering torn tail ({} bytes truncated)",
            path.display(),
            bytes.len() as u64 - valid
        );
        let f = OpenOptions::new().write(true).open(path)?;
        f.set_len(valid)?;
        f.sync_data()?;
    }
    Ok(valid)
}

/// Length of the longest prefix of `bytes` consisting of complete,
/// newline-terminated lines whose **last** line parses as JSON; trailing
/// partial or garbage lines are excluded (iteratively, so a torn write
/// that spilled across lines is fully dropped).
fn valid_prefix_len(bytes: &[u8]) -> u64 {
    let mut end = bytes.len();
    loop {
        let Some(nl) = bytes[..end].iter().rposition(|&b| b == b'\n') else {
            return 0;
        };
        let start = bytes[..nl]
            .iter()
            .rposition(|&b| b == b'\n')
            .map(|i| i + 1)
            .unwrap_or(0);
        let line = &bytes[start..nl];
        if !line.is_empty() {
            if let Ok(s) = std::str::from_utf8(line) {
                if Json::parse(s).is_ok() {
                    return (nl + 1) as u64;
                }
            }
        }
        if start == 0 {
            return 0;
        }
        end = start;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "cs-journal-{tag}-{}-{:x}",
            std::process::id(),
            crate::util::fnv1a(tag.as_bytes())
        ));
        let _ = fs::remove_dir_all(&dir);
        dir
    }

    fn record(i: usize) -> Json {
        Json::obj(vec![
            ("kind", Json::Str("span".into())),
            ("i", Json::Num(i as f64)),
        ])
    }

    #[test]
    fn append_read_roundtrip() {
        let dir = tmp_dir("roundtrip");
        let j = Journal::open(JournalConfig::new(&dir)).unwrap();
        for i in 0..5 {
            j.append(&record(i));
        }
        j.flush();
        assert_eq!(j.appended(), 5);
        assert_eq!(j.errors(), 0);
        let records = read_records(&dir).unwrap();
        assert_eq!(records.len(), 5);
        assert_eq!(records[4].get("i").and_then(Json::as_f64), Some(4.0));
        drop(j);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn rotation_and_total_cap_bound_disk() {
        let dir = tmp_dir("rotate");
        let cfg = JournalConfig {
            max_file_bytes: 1024,
            max_total_bytes: 3 * 1024,
            fsync: FsyncPolicy::Rotate,
            ..JournalConfig::new(&dir)
        };
        let j = Journal::open(cfg).unwrap();
        // ~60 bytes per record → a few KiB forces several rotations and
        // oldest-file eviction under the 3 KiB total cap.
        for i in 0..200 {
            j.append(&record(i));
        }
        j.flush();
        let files = list_files(&dir).unwrap();
        assert!(files.len() >= 2, "rotation must have produced several files");
        let total: u64 = files
            .iter()
            .map(|(_, p)| fs::metadata(p).map(|m| m.len()).unwrap_or(0))
            .sum();
        // cap + one active file of slack (eviction runs at rotation)
        assert!(total <= 4 * 1024, "total {total} exceeds cap+slack");
        // the retained suffix is contiguous and ends with the last record
        let records = read_records(&dir).unwrap();
        assert!(!records.is_empty());
        let last = records.last().unwrap().get("i").and_then(Json::as_f64);
        assert_eq!(last, Some(199.0));
        drop(j);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_tail_is_recovered_on_open() {
        let dir = tmp_dir("torn");
        {
            let j = Journal::open(JournalConfig::new(&dir)).unwrap();
            for i in 0..3 {
                j.append(&record(i));
            }
            j.flush();
        }
        // simulate a crash mid-write: a partial record with no newline
        let (_, path) = list_files(&dir).unwrap().pop().unwrap();
        let clean_len = fs::metadata(&path).unwrap().len();
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"kind\":\"span\",\"tor").unwrap();
        }
        // reopen: the torn bytes are truncated, appends resume cleanly
        let j = Journal::open(JournalConfig::new(&dir)).unwrap();
        assert_eq!(fs::metadata(&path).unwrap().len(), clean_len);
        j.append(&record(3));
        j.flush();
        let records = read_records(&dir).unwrap();
        let ids: Vec<f64> = records
            .iter()
            .filter_map(|r| r.get("i").and_then(Json::as_f64))
            .collect();
        assert_eq!(ids, vec![0.0, 1.0, 2.0, 3.0]);
        drop(j);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_whole_garbage_line_is_also_dropped() {
        let dir = tmp_dir("garbage");
        {
            let j = Journal::open(JournalConfig::new(&dir)).unwrap();
            j.append(&record(0));
            j.flush();
        }
        let (_, path) = list_files(&dir).unwrap().pop().unwrap();
        let clean_len = fs::metadata(&path).unwrap().len();
        {
            let mut f = OpenOptions::new().append(true).open(&path).unwrap();
            // a complete line of garbage AND a partial tail
            f.write_all(b"!!corrupted!!\n{\"par").unwrap();
        }
        let _ = Journal::open(JournalConfig::new(&dir)).unwrap();
        assert_eq!(fs::metadata(&path).unwrap().len(), clean_len);
        let records = read_records(&dir).unwrap();
        assert_eq!(records.len(), 1);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn append_faults_are_counted_never_propagated() {
        use crate::util::failpoint;
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        let dir = tmp_dir("chaos-append");
        let j = Journal::open(JournalConfig::new(&dir)).unwrap();
        // panic kind at a no-panic site: downgraded to a counted error
        failpoint::arm_from_str("journal.append:1:panic:3").unwrap();
        for i in 0..4 {
            j.append(&record(i));
        }
        failpoint::disarm_all();
        assert_eq!(j.appended(), 0);
        assert_eq!(j.errors(), 4);
        // disarmed appends resume cleanly on the same handle
        j.append(&record(9));
        j.flush();
        assert_eq!(j.appended(), 1);
        let records = read_records(&dir).unwrap();
        assert_eq!(records.len(), 1);
        assert_eq!(records[0].get("i").and_then(Json::as_f64), Some(9.0));
        drop(j);
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn custom_prefix_journals_coexist_in_one_dir() {
        let dir = tmp_dir("prefix");
        let t = Journal::open(JournalConfig::new(&dir)).unwrap();
        let w = Journal::open(JournalConfig {
            file_prefix: "wal.".into(),
            ..JournalConfig::new(&dir)
        })
        .unwrap();
        t.append(&record(1));
        w.append(&record(2));
        t.flush();
        w.flush();
        let telemetry = read_records(&dir).unwrap();
        assert_eq!(telemetry.len(), 1);
        assert_eq!(telemetry[0].get("i").and_then(Json::as_f64), Some(1.0));
        let wal = read_records_with_prefix(&dir, "wal.").unwrap();
        assert_eq!(wal.len(), 1);
        assert_eq!(wal[0].get("i").and_then(Json::as_f64), Some(2.0));
        drop((t, w));
        let _ = fs::remove_dir_all(&dir);
    }

    #[test]
    fn fsync_policy_parses_and_roundtrips() {
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(FsyncPolicy::parse("rotate").unwrap(), FsyncPolicy::Rotate);
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert!(FsyncPolicy::parse("sometimes").is_err());
        for p in [FsyncPolicy::Never, FsyncPolicy::Rotate, FsyncPolicy::Always] {
            assert_eq!(FsyncPolicy::parse(p.as_str()).unwrap(), p);
        }
    }

    #[test]
    fn open_rejects_degenerate_bounds() {
        let dir = tmp_dir("bounds");
        let mut cfg = JournalConfig::new(&dir);
        cfg.max_file_bytes = 10;
        assert!(Journal::open(cfg.clone()).is_err());
        cfg.max_file_bytes = 2048;
        cfg.max_total_bytes = 1024;
        assert!(Journal::open(cfg).is_err());
        let _ = fs::remove_dir_all(&dir);
    }
}
