"""L1 Pallas kernel: fused MSET2 estimation step.

Computes, in one kernel, the surveillance back-end that follows the
similarity kernel:

    W  = G · K        (m × B)   weight solve against the trained inverse
    X̂  = Wᵀ · D       (B × n)   state estimate
    R  = X − X̂        (B × n)   residuals

Fusing the two matmuls and the subtraction keeps W entirely in VMEM — it
is never materialised in HBM, which is the TPU analogue of the paper's
"close attention is paid to efficient reuse of memory" for the CUDA
implementation (§II.D).

The whole G (m × m) is staged per grid step; at the largest bucket
(m = 512) that is 1 MiB — comfortably inside VMEM next to the (m × TB)
strip of K and the (m × n) memory matrix (512·128·4 ≈ 256 KiB each).
Grid is over observation tiles only.
"""

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _estimate_kernel(g_ref, k_ref, d_ref, x_ref, xhat_ref, resid_ref):
    g = g_ref[...]                      # (m, m)
    k = k_ref[...]                      # (m, TB)
    d = d_ref[...]                      # (m, n)
    x = x_ref[...]                      # (TB, n)
    # MXU matmul #1: weights stay in VMEM.
    w = jax.lax.dot_general(
        g, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                   # (m, TB)
    # MXU matmul #2: contract over the memory dimension.
    xhat = jax.lax.dot_general(
        w, d, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                   # (TB, n)
    xhat_ref[...] = xhat
    resid_ref[...] = x - xhat


@functools.partial(jax.jit, static_argnames=("tb",))
def estimate_pallas(g, k, d, x, tb=128):
    """Fused estimate: returns (xhat, resid), both (B, n) f32.

    g: (m, m) trained inverse, k: (m, B) masked similarities,
    d: (m, n) memory matrix, x: (B, n) observation chunk.
    """
    m, b = k.shape
    n = d.shape[1]
    assert g.shape == (m, m) and d.shape[0] == m and x.shape == (b, n)
    tb = math.gcd(b, tb)
    grid = (b // tb,)
    return pl.pallas_call(
        _estimate_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((m, m), lambda j: (0, 0)),   # G resident
            pl.BlockSpec((m, tb), lambda j: (0, j)),  # K strip
            pl.BlockSpec((m, n), lambda j: (0, 0)),   # D resident
            pl.BlockSpec((tb, n), lambda j: (j, 0)),  # X strip
        ],
        out_specs=[
            pl.BlockSpec((tb, n), lambda j: (j, 0)),
            pl.BlockSpec((tb, n), lambda j: (j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=True,
    )(g, k, d, x)


def vmem_bytes(m, tb, n, dtype_bytes=4):
    """VMEM working set per grid step (perf analysis)."""
    return (m * m + m * tb + m * n + 2 * tb * n + m * tb) * dtype_bytes
