//! Deterministic workload-trace generators: tenant arrivals and per-tenant
//! demand series.
//!
//! Everything derives from the scenario's root seed through tagged
//! [`Rng::fork`]s, so a scenario replays bit-identically: same arrivals,
//! same phases, same jitter — independent of thread count or scheduling
//! (the same invariant the sweep engine holds for trial seeds).
//!
//! Demand value of tenant `i` at epoch `t` since its arrival:
//!
//! ```text
//! d_i(t) = base · growth^t · kind_factor(t + phase_i) · jitter_i
//! ```
//!
//! In direct mode `d_i(t)` is core-equivalent demand; in workload mode it
//! multiplies the workload's `obs_per_sec` before the surface oracle
//! converts observations/second into core-equivalents.

use crate::scenario::spec::{DemandKind, ScenarioSpec, WorkloadSpec};
use crate::util::fnv1a;
use crate::util::rng::Rng;

/// One synthesized tenant: when it arrived and its raw demand-multiplier
/// series (one value per epoch from `arrival_epoch` to the scenario end).
#[derive(Clone, Debug, PartialEq)]
pub struct Tenant {
    /// Stable tenant index (also its RNG tag).
    pub id: usize,
    /// Epoch the tenant joins the fleet.
    pub arrival_epoch: usize,
    /// Demand multiplier per lived epoch (`epochs - arrival_epoch` values).
    pub series: Vec<f64>,
}

/// Sample a Poisson count (Knuth's product-of-uniforms; exact for the
/// small per-epoch rates scenarios use).
fn poisson(rng: &mut Rng, rate: f64) -> usize {
    if rate <= 0.0 {
        return 0;
    }
    let l = (-rate).exp();
    let mut k = 0usize;
    let mut p = 1.0;
    loop {
        p *= rng.f64();
        if p <= l {
            return k;
        }
        k += 1;
    }
}

/// Arrival epoch per tenant: `initial` tenants at epoch 0, then Poisson
/// arrivals each epoch, truncated at `max_tenants`.
pub fn arrival_epochs(spec: &ScenarioSpec) -> Vec<usize> {
    let mut rng = Rng::new(spec.seed).fork(fnv1a(b"scenario.arrivals"));
    let cap = spec.arrivals.max_tenants;
    let mut arrivals = vec![0usize; spec.arrivals.initial.min(cap)];
    for epoch in 1..spec.epochs {
        if arrivals.len() >= cap {
            break;
        }
        let k = poisson(&mut rng, spec.arrivals.rate_per_epoch);
        for _ in 0..k {
            if arrivals.len() >= cap {
                break;
            }
            arrivals.push(epoch);
        }
    }
    arrivals
}

/// The demand-multiplier series of tenant `id` over `len` epochs.
pub fn demand_series(spec: &ScenarioSpec, id: usize, len: usize) -> Vec<f64> {
    let mut rng = Rng::new(spec.seed).fork(fnv1a(b"scenario.tenant").wrapping_add(id as u64));
    let d = &spec.demand;
    // Per-tenant size jitter (lognormal; exp(0·g) = 1 exactly when off).
    let scale = (d.jitter * rng.gauss()).exp();
    // Per-tenant phase offset for cyclic kinds.
    let phase = match d.kind {
        DemandKind::Diurnal { period, .. } => rng.range_usize(0, period),
        DemandKind::Flash { every, .. } => rng.range_usize(0, every),
        _ => 0,
    };
    (0..len)
        .map(|t| {
            let factor = match d.kind {
                DemandKind::Constant => 1.0,
                DemandKind::Steps { every } => 2f64.powi((t / every) as i32),
                DemandKind::Diurnal { amplitude, period } => {
                    let angle = 2.0 * std::f64::consts::PI * ((t + phase) as f64)
                        / (period as f64);
                    (1.0 + amplitude * angle.sin()).max(0.0)
                }
                DemandKind::Flash { spike, every, width } => {
                    if (t + phase) % every < width {
                        spike
                    } else {
                        1.0
                    }
                }
            };
            d.base * d.growth_per_epoch.powi(t as i32) * factor * scale
        })
        .collect()
}

/// Synthesize the whole fleet for a scenario.
pub fn build_tenants(spec: &ScenarioSpec) -> Vec<Tenant> {
    arrival_epochs(spec)
        .into_iter()
        .enumerate()
        .map(|(id, arrival_epoch)| Tenant {
            id,
            arrival_epoch,
            series: demand_series(spec, id, spec.epochs - arrival_epoch),
        })
        .collect()
}

/// Ceiling on a drifted design parameter: far beyond any measurable cell,
/// but small enough that the `f64 → usize` cast cannot saturate and the
/// sweep engine's `2 * n` gap arithmetic cannot overflow when a runaway
/// geometric drift (e.g. `×2` per epoch) is simulated.
pub const DRIFT_CEILING: usize = 1 << 20;

/// Tenant `id`'s drifted ML design parameters at epoch `t` since arrival:
/// the base workload's `(n_signals, n_memvec)` scaled by the per-epoch
/// drift factors, rounded to the integer grid, clamped to
/// `[1, DRIFT_CEILING]`.
pub fn drifted_params(w: &WorkloadSpec, t: usize) -> (usize, usize) {
    let clamp = |x: f64| (x.round().min(DRIFT_CEILING as f64) as usize).max(1);
    let n = (w.base.n_signals as f64) * w.drift.signals_growth.powi(t as i32);
    let m = (w.base.n_memvec as f64) * w.drift.memvecs_growth.powi(t as i32);
    (clamp(n), clamp(m))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::spec::{ArrivalSpec, DemandSpec};
    use crate::shapes::Workload;

    fn spec() -> ScenarioSpec {
        ScenarioSpec {
            epochs: 60,
            arrivals: ArrivalSpec {
                initial: 5,
                rate_per_epoch: 0.8,
                max_tenants: 30,
            },
            ..ScenarioSpec::default()
        }
    }

    #[test]
    fn arrivals_deterministic_capped_and_ordered() {
        let s = spec();
        let a = arrival_epochs(&s);
        let b = arrival_epochs(&s);
        assert_eq!(a, b, "arrivals must replay bit-identically");
        assert!(a.len() <= s.arrivals.max_tenants);
        assert!(a.len() >= s.arrivals.initial);
        assert!(a.iter().take(5).all(|&e| e == 0), "initial tenants at epoch 0");
        assert!(a.windows(2).all(|w| w[0] <= w[1]), "arrivals sorted by epoch");
        assert!(a.iter().all(|&e| e < s.epochs));
        // a different seed produces a different fleet
        let other = arrival_epochs(&ScenarioSpec { seed: 99, ..s });
        assert_ne!(a, other);
    }

    #[test]
    fn series_deterministic_and_nonnegative_all_kinds() {
        for kind in [
            DemandKind::Constant,
            DemandKind::Steps { every: 10 },
            DemandKind::Diurnal {
                amplitude: 0.9,
                period: 7,
            },
            DemandKind::Flash {
                spike: 5.0,
                every: 12,
                width: 2,
            },
        ] {
            let s = ScenarioSpec {
                demand: DemandSpec {
                    base: 0.5,
                    growth_per_epoch: 1.01,
                    jitter: 0.2,
                    kind,
                },
                ..spec()
            };
            let a = demand_series(&s, 3, 60);
            assert_eq!(a, demand_series(&s, 3, 60));
            assert!(a.iter().all(|v| v.is_finite() && *v >= 0.0), "{kind:?}");
            assert_ne!(a, demand_series(&s, 4, 60), "tenants differ");
        }
    }

    #[test]
    fn degenerate_constant_matches_exponential_bitwise() {
        // jitter 0 + constant kind must reproduce GrowthTrace::exponential
        // exactly — the fleet engine's bit-identity bridge to
        // shapes::elastic.
        let s = ScenarioSpec {
            demand: DemandSpec {
                base: 0.5,
                growth_per_epoch: 1.04,
                jitter: 0.0,
                kind: DemandKind::Constant,
            },
            ..spec()
        };
        let series = demand_series(&s, 0, 80);
        let reference = crate::shapes::elastic::GrowthTrace::exponential(0.5, 1.04, 80, 24.0)
            .unwrap();
        assert_eq!(series, reference.demand());
    }

    #[test]
    fn flash_spikes_and_diurnal_cycles_present() {
        let s = ScenarioSpec {
            demand: DemandSpec {
                base: 1.0,
                growth_per_epoch: 1.0,
                jitter: 0.0,
                kind: DemandKind::Flash {
                    spike: 4.0,
                    every: 10,
                    width: 2,
                },
            },
            ..spec()
        };
        let v = demand_series(&s, 1, 60);
        let spikes = v.iter().filter(|&&x| x == 4.0).count();
        assert_eq!(spikes, 12, "2-wide spike every 10 epochs over 60");
        let s = ScenarioSpec {
            demand: DemandSpec {
                base: 1.0,
                growth_per_epoch: 1.0,
                jitter: 0.0,
                kind: DemandKind::Diurnal {
                    amplitude: 0.5,
                    period: 7,
                },
            },
            ..spec()
        };
        let v = demand_series(&s, 1, 70);
        let (lo, hi) = v
            .iter()
            .fold((f64::MAX, f64::MIN), |(lo, hi), &x| (lo.min(x), hi.max(x)));
        assert!(hi > 1.2 && lo < 0.8, "cycle must swing around the mean");
    }

    #[test]
    fn drift_moves_across_the_grid() {
        let w = WorkloadSpec {
            base: Workload {
                n_signals: 8,
                n_memvec: 32,
                obs_per_sec: 1.0,
                train_window: 256,
            },
            drift: crate::scenario::spec::WorkloadDrift {
                signals_growth: 1.01,
                memvecs_growth: 1.02,
            },
        };
        assert_eq!(drifted_params(&w, 0), (8, 32));
        let (n, m) = drifted_params(&w, 100);
        assert!(n > 8 && m > 32);
        // no-drift default is the identity
        let w0 = WorkloadSpec {
            drift: Default::default(),
            ..w
        };
        assert_eq!(drifted_params(&w0, 500), (8, 32));
        // runaway geometric drift clamps at the ceiling instead of
        // saturating the cast / overflowing gap arithmetic downstream
        let runaway = WorkloadSpec {
            drift: crate::scenario::spec::WorkloadDrift {
                signals_growth: 2.0,
                memvecs_growth: 2.0,
            },
            ..w
        };
        assert_eq!(drifted_params(&runaway, 500), (DRIFT_CEILING, DRIFT_CEILING));
    }

    #[test]
    fn build_tenants_assembles_fleet() {
        let s = spec();
        let fleet = build_tenants(&s);
        assert!(fleet.len() >= 5);
        for t in &fleet {
            assert_eq!(t.series.len(), s.epochs - t.arrival_epoch);
        }
        assert_eq!(fleet, build_tenants(&s));
    }
}
