//! Dependency-free HTTP/1.1 server core.
//!
//! `hyper`/`axum` are unavailable in the offline build environment; the
//! service's needs are small — parse a request, dispatch to a handler,
//! write a JSON response — so a std `TcpListener` accept loop fanning
//! connections out over [`crate::util::threadpool::TrialExecutor`] covers
//! them (one registered job holds the connection queue).
//!
//! Protocol subset (documented, deliberate):
//! - one request per connection (`Connection: close` on every response);
//! - bodies bounded by `Content-Length` (no chunked transfer encoding);
//! - no percent-decoding — all structured data travels in JSON bodies.

use crate::metrics::Registry;
use crate::util::json::Json;
use crate::util::threadpool::TrialExecutor;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Duration;

/// Largest accepted request body.
const MAX_BODY_BYTES: usize = 1 << 20;
/// Largest accepted request line + headers, in bytes (caps `read_line`
/// growth — a client streaming garbage without newlines hits EOF here).
const MAX_HEAD_BYTES: u64 = 8 << 10;
/// Largest accepted header count.
const MAX_HEADERS: usize = 64;
/// Per-read socket timeout.
const READ_TIMEOUT: Duration = Duration::from_secs(10);
/// Whole-request deadline (defeats byte-at-a-time trickle within the
/// per-read timeout).
const REQUEST_DEADLINE: Duration = Duration::from_secs(30);
/// Connections admitted concurrently (handling + queued for a pool
/// thread); beyond this the accept loop answers 503 and closes rather
/// than buffering sockets without bound.
const MAX_PENDING_CONNS: usize = 64;

/// A parsed request.
#[derive(Debug)]
pub struct Request {
    /// HTTP method (upper-case).
    pub method: String,
    /// Path without the query string.
    pub path: String,
    /// Raw `k=v` query pairs (no percent-decoding).
    pub query: Vec<(String, String)>,
    /// Lower-cased header names with trimmed values.
    pub headers: Vec<(String, String)>,
    /// Raw body bytes.
    pub body: Vec<u8>,
}

impl Request {
    /// First query-string value for `key`.
    pub fn query_get(&self, key: &str) -> Option<&str> {
        self.query
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// Body as UTF-8 (errors on invalid encodings).
    pub fn body_str(&self) -> anyhow::Result<&str> {
        std::str::from_utf8(&self.body).map_err(|_| anyhow::anyhow!("body is not valid UTF-8"))
    }

    /// First header value for `name` (header names are stored
    /// lower-cased; pass `name` in lower case).
    pub fn header_get(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    /// The request's correlation ID: the first non-empty `x-request-id`
    /// header. The connection handler mints one when the client sent
    /// none, so handlers always observe `Some`.
    pub fn request_id(&self) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, v)| k == "x-request-id" && !v.trim().is_empty())
            .map(|(_, v)| v.as_str())
    }
}

/// A response ready to serialize.
#[derive(Debug)]
pub struct Response {
    /// HTTP status code.
    pub status: u16,
    /// `Content-Type` header value.
    pub content_type: &'static str,
    /// Raw body bytes.
    pub body: Vec<u8>,
}

impl Response {
    /// JSON response with the given status.
    pub fn json(status: u16, body: &Json) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.to_string().into_bytes(),
        }
    }

    /// Plain-text response with the given status.
    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
        }
    }

    /// JSON error envelope: `{"error": msg}`.
    pub fn error(status: u16, msg: &str) -> Response {
        Response::json(status, &Json::obj(vec![("error", Json::Str(msg.to_string()))]))
    }

    fn reason(status: u16) -> &'static str {
        match status {
            200 => "OK",
            202 => "Accepted",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        self.write_with_request_id(stream, None)
    }

    fn write_with_request_id(
        &self,
        stream: &mut TcpStream,
        request_id: Option<&str>,
    ) -> std::io::Result<()> {
        let rid = match request_id {
            Some(id) => format!("x-request-id: {id}\r\n"),
            None => String::new(),
        };
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n{rid}Connection: close\r\n\r\n",
            self.status,
            Response::reason(self.status),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// A `Read` over a borrowed `TcpStream` that enforces an absolute deadline:
/// every read gets a socket timeout of `min(remaining, READ_TIMEOUT)`, so a
/// byte-at-a-time trickle cannot hold a handler thread past the deadline.
struct DeadlineStream<'a> {
    stream: &'a TcpStream,
    deadline: std::time::Instant,
}

impl Read for DeadlineStream<'_> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let remaining = self
            .deadline
            .saturating_duration_since(std::time::Instant::now());
        if remaining.is_zero() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::TimedOut,
                "request deadline exceeded",
            ));
        }
        self.stream
            .set_read_timeout(Some(remaining.min(READ_TIMEOUT)))?;
        (&mut &*self.stream).read(buf)
    }
}

fn read_request(stream: &mut TcpStream) -> anyhow::Result<Request> {
    let deadline = std::time::Instant::now() + REQUEST_DEADLINE;
    // The head (request line + headers) is read through a hard byte cap;
    // the body allowance is added only after Content-Length is validated.
    let mut reader = BufReader::new(Read::take(
        DeadlineStream {
            stream: &*stream,
            deadline,
        },
        MAX_HEAD_BYTES,
    ));
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.split_whitespace();
    let method = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| anyhow::anyhow!("missing request target"))?
        .to_string();
    let version = parts.next().unwrap_or("");
    anyhow::ensure!(
        version.starts_with("HTTP/1."),
        "unsupported protocol '{version}'"
    );

    let mut headers = Vec::new();
    let mut content_len = 0usize;
    loop {
        anyhow::ensure!(
            std::time::Instant::now() < deadline,
            "request deadline exceeded"
        );
        let mut h = String::new();
        let n = reader.read_line(&mut h)?;
        anyhow::ensure!(n > 0, "unexpected eof in headers (or head too large)");
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        let (k, v) = h
            .split_once(':')
            .ok_or_else(|| anyhow::anyhow!("malformed header line"))?;
        let k = k.trim().to_ascii_lowercase();
        let v = v.trim().to_string();
        if k == "content-length" {
            content_len = v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad content-length '{v}'"))?;
        }
        headers.push((k, v));
        anyhow::ensure!(headers.len() <= MAX_HEADERS, "too many headers");
    }
    anyhow::ensure!(
        content_len <= MAX_BODY_BYTES,
        "body too large ({content_len} bytes)"
    );
    anyhow::ensure!(
        std::time::Instant::now() < deadline,
        "request deadline exceeded"
    );
    // Extend the read cap to cover exactly the declared body.
    reader.get_mut().set_limit(content_len as u64);
    let mut body = vec![0u8; content_len];
    reader.read_exact(&mut body)?;

    let (path, query_raw) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q),
        None => (target.clone(), ""),
    };
    let query = query_raw
        .split('&')
        .filter(|p| !p.is_empty())
        .map(|p| {
            let (k, v) = p.split_once('=').unwrap_or((p, ""));
            (k.to_string(), v.to_string())
        })
        .collect();
    Ok(Request {
        method,
        path,
        query,
        headers,
        body,
    })
}

/// Connection handler signature: pure request → response.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync + 'static>;

fn handle_connection(mut stream: TcpStream, handler: Handler) {
    let _ = stream.set_read_timeout(Some(READ_TIMEOUT));
    let t0 = std::time::Instant::now();
    let (resp, request_id, line) = match read_request(&mut stream) {
        Ok(mut req) => {
            // Honour the caller's correlation ID; mint one otherwise and
            // inject it so handlers observe the same ID the access log
            // and response header carry.
            let rid = match req.request_id() {
                Some(id) => id.to_string(),
                None => {
                    let id = crate::obs::mint_trace_id();
                    req.headers.push(("x-request-id".to_string(), id.clone()));
                    id
                }
            };
            let line = format!("{} {}", req.method, req.path);
            ((*handler)(&req), rid, line)
        }
        Err(e) => (
            Response::error(400, &format!("bad request: {e}")),
            crate::obs::mint_trace_id(),
            "<unparsed>".to_string(),
        ),
    };
    let elapsed = t0.elapsed();
    let reg = Registry::global();
    reg.time("service.http.request_seconds", elapsed);
    reg.inc(match resp.status / 100 {
        2 => "service.http.responses.2xx",
        4 => "service.http.responses.4xx",
        5 => "service.http.responses.5xx",
        _ => "service.http.responses.other",
    });
    if crate::obs::access_log_enabled() {
        log::info!(
            target: "http.access",
            "{line} {} {:.3}ms id={request_id}",
            resp.status,
            elapsed.as_secs_f64() * 1e3
        );
    }
    if let Err(e) = resp.write_with_request_id(&mut stream, Some(&request_id)) {
        log::debug!("http: response write failed: {e}");
    }
}

/// Accept loop + connection thread pool over a generic [`Handler`].
pub struct HttpServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl HttpServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and serve
    /// connections on `workers` pool threads until shutdown/drop.
    pub fn bind(addr: &str, workers: usize, handler: Handler) -> anyhow::Result<HttpServer> {
        let listener =
            TcpListener::bind(addr).map_err(|e| anyhow::anyhow!("bind {addr}: {e}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = Arc::clone(&stop);
        let accept = std::thread::Builder::new()
            .name("http-accept".into())
            .spawn(move || {
                let pool = TrialExecutor::new(workers.max(1), false);
                let conns = pool.register(1.0);
                let pending = Arc::new(AtomicUsize::new(0));
                for conn in listener.incoming() {
                    if stop2.load(Ordering::SeqCst) {
                        break;
                    }
                    match conn {
                        Ok(mut stream) => {
                            if pending.load(Ordering::SeqCst) >= MAX_PENDING_CONNS {
                                // Shed load instead of buffering sockets
                                // without bound behind a busy pool.
                                Registry::global().inc("service.http.responses.5xx");
                                let _ = Response::error(503, "server busy; retry later")
                                    .write_to(&mut stream);
                                continue;
                            }
                            pending.fetch_add(1, Ordering::SeqCst);
                            let h = Arc::clone(&handler);
                            let p = Arc::clone(&pending);
                            conns.submit(move || {
                                // A panicking handler must not kill the
                                // pool worker or leak its pending slot.
                                let r = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(move || {
                                        handle_connection(stream, h)
                                    }),
                                );
                                if r.is_err() {
                                    log::error!("http: connection handler panicked");
                                }
                                p.fetch_sub(1, Ordering::SeqCst);
                            });
                        }
                        Err(e) => log::warn!("http: accept failed: {e}"),
                    }
                }
                drop(conns);
                pool.shutdown();
            })?;
        Ok(HttpServer {
            addr: local,
            stop,
            accept: Some(accept),
        })
    }

    /// The bound address (with the real port when bound to port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting, drain in-flight connections, join the accept thread.
    pub fn shutdown(self) {
        drop(self);
    }

    /// Block until the accept loop exits (serve-forever mode).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for HttpServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept() so the loop observes the stop flag.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> HttpServer {
        let handler: Handler = Arc::new(|req: &Request| {
            Response::json(
                200,
                &Json::obj(vec![
                    ("method", Json::Str(req.method.clone())),
                    ("path", Json::Str(req.path.clone())),
                    (
                        "q",
                        Json::Str(req.query_get("q").unwrap_or("").to_string()),
                    ),
                    (
                        "body",
                        Json::Str(req.body_str().unwrap_or("").to_string()),
                    ),
                ]),
            )
        });
        HttpServer::bind("127.0.0.1:0", 2, handler).unwrap()
    }

    fn raw_roundtrip(addr: SocketAddr, raw: &str) -> String {
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut out = String::new();
        s.read_to_string(&mut out).unwrap();
        out
    }

    #[test]
    fn parses_and_echoes_request() {
        let server = echo_server();
        let body = r#"{"x":1}"#;
        let raw = format!(
            "POST /v1/echo?q=7 HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        let out = raw_roundtrip(server.addr(), &raw);
        assert!(out.starts_with("HTTP/1.1 200 OK\r\n"), "{out}");
        let payload = out.split("\r\n\r\n").nth(1).unwrap();
        let j = Json::parse(payload).unwrap();
        assert_eq!(j.get("method").unwrap().as_str(), Some("POST"));
        assert_eq!(j.get("path").unwrap().as_str(), Some("/v1/echo"));
        assert_eq!(j.get("q").unwrap().as_str(), Some("7"));
        assert_eq!(j.get("body").unwrap().as_str(), Some(body));
        server.shutdown();
    }

    #[test]
    fn malformed_requests_get_400() {
        let server = echo_server();
        let out = raw_roundtrip(server.addr(), "NONSENSE\r\n\r\n");
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        let out = raw_roundtrip(
            server.addr(),
            "GET / HTTP/1.1\r\nContent-Length: 99999999999\r\n\r\n",
        );
        assert!(out.starts_with("HTTP/1.1 400 "), "{out}");
        server.shutdown();
    }

    #[test]
    fn request_id_is_honoured_or_minted_and_echoed() {
        let server = echo_server();
        let out = raw_roundtrip(
            server.addr(),
            "GET / HTTP/1.1\r\nHost: t\r\nX-Request-Id: my-id-7\r\n\r\n",
        );
        assert!(out.contains("x-request-id: my-id-7"), "{out}");
        let out = raw_roundtrip(server.addr(), "GET / HTTP/1.1\r\nHost: t\r\n\r\n");
        let rid = out
            .lines()
            .find_map(|l| l.strip_prefix("x-request-id: "))
            .expect("minted id echoed");
        assert!(!rid.trim().is_empty(), "{out}");
        server.shutdown();
    }

    #[test]
    fn concurrent_connections_are_served() {
        let server = echo_server();
        let addr = server.addr();
        std::thread::scope(|scope| {
            for i in 0..8 {
                scope.spawn(move || {
                    let raw = format!("GET /c/{i} HTTP/1.1\r\nHost: t\r\n\r\n");
                    let out = raw_roundtrip(addr, &raw);
                    assert!(out.contains(&format!("/c/{i}")), "{out}");
                });
            }
        });
        server.shutdown();
    }

    #[test]
    fn shutdown_unblocks_accept() {
        let server = echo_server();
        let t0 = std::time::Instant::now();
        server.shutdown();
        assert!(t0.elapsed() < Duration::from_secs(5), "shutdown hung");
    }
}
