//! Runtime metrics: counters, gauges, and **bounded** latency histograms
//! with text/JSON/Prometheus export.
//!
//! The coordinator, executor, and service record device calls, cache hits,
//! trial counts, per-phase timings and HTTP latencies here;
//! `containerstress … --metrics` dumps the registry at exit and
//! `GET /metrics` serves it live (`?format=json|text|prometheus`).
//!
//! Histograms are log-bucketed with fixed memory ([`Histogram`]): a
//! long-lived `serve` process can record samples forever without growing —
//! the unbounded `Vec<f64>` store this replaced is gone. Quantiles carry
//! ≤ 5% relative error (documented on [`Histogram`]); counts, sums, means,
//! min/max are exact. See `docs/API.md` for the metric catalog.

mod histogram;

pub use histogram::Histogram;

use crate::util::json::Json;
use crate::util::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Global-or-local metrics registry (thread-safe).
#[derive(Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, u64>>,
    histograms: Mutex<BTreeMap<String, Histogram>>,
    gauges: Mutex<BTreeMap<String, f64>>,
}

impl Registry {
    /// Fresh, empty registry (tests; production uses [`Registry::global`]).
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Process-wide registry.
    pub fn global() -> &'static Registry {
        static GLOBAL: std::sync::OnceLock<Registry> = std::sync::OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Increment a counter by one.
    pub fn inc(&self, name: &str) {
        self.add(name, 1);
    }

    /// Add `v` to a counter.
    pub fn add(&self, name: &str, v: u64) {
        *self
            .counters
            .lock()
            .unwrap()
            .entry(name.to_string())
            .or_insert(0) += v;
    }

    /// Record a duration sample under `name`.
    pub fn time(&self, name: &str, d: Duration) {
        self.sample(name, d.as_secs_f64());
    }

    /// Record one observation into the bounded histogram under `name`.
    pub fn sample(&self, name: &str, v: f64) {
        let mut hs = self.histograms.lock().unwrap();
        match hs.get_mut(name) {
            Some(h) => h.record(v),
            None => {
                let mut h = Histogram::new();
                h.record(v);
                hs.insert(name.to_string(), h);
            }
        }
    }

    /// Set a gauge to an instantaneous value (last write wins).
    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauges.lock().unwrap().insert(name.to_string(), v);
    }

    /// Current value of a gauge, if ever set.
    pub fn gauge(&self, name: &str) -> Option<f64> {
        self.gauges.lock().unwrap().get(name).copied()
    }

    /// Current value of a counter (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        self.counters
            .lock()
            .unwrap()
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Snapshot of the histogram under `name`, if any samples were
    /// recorded (a clone — cheap and fixed-size, usable for merging).
    pub fn histogram(&self, name: &str) -> Option<Histogram> {
        self.histograms.lock().unwrap().get(name).cloned()
    }

    /// Summary statistics of a sampled series, if any were recorded.
    /// `n`/`mean`/`std`/`min`/`max` are exact; quantiles carry the
    /// [`Histogram`] error bound (≤ 5% relative).
    pub fn summary(&self, name: &str) -> Option<Summary> {
        self.histograms
            .lock()
            .unwrap()
            .get(name)
            .and_then(Histogram::summary)
    }

    /// Human-readable dump.
    pub fn render(&self) -> String {
        let mut out = String::from("=== metrics ===\n");
        for (k, v) in self.counters.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            out.push_str(&format!("{k}: {v:.3}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let Some(s) = h.summary() else { continue };
            out.push_str(&format!(
                "{k}: n={} median={:.3e}s mean={:.3e}s p75={:.3e}s\n",
                s.n, s.median, s.mean, s.p75
            ));
        }
        out
    }

    /// JSON export (counters + gauges + histogram summaries).
    pub fn to_json(&self) -> Json {
        let mut counters = BTreeMap::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            counters.insert(k.clone(), Json::Num(*v as f64));
        }
        let mut gauges = BTreeMap::new();
        for (k, v) in self.gauges.lock().unwrap().iter() {
            gauges.insert(k.clone(), Json::Num(*v));
        }
        let mut samples = BTreeMap::new();
        for (k, h) in self.histograms.lock().unwrap().iter() {
            let Some(s) = h.summary() else { continue };
            samples.insert(
                k.clone(),
                Json::obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("median", Json::Num(s.median)),
                    ("mean", Json::Num(s.mean)),
                    ("min", Json::Num(s.min)),
                    ("max", Json::Num(s.max)),
                    ("p95", Json::Num(h.quantile(0.95).unwrap_or(s.max))),
                ]),
            );
        }
        Json::obj(vec![
            ("counters", Json::Obj(counters)),
            ("gauges", Json::Obj(gauges)),
            ("timers", Json::Obj(samples)),
        ])
    }

    /// Prometheus text-exposition rendering (format version 0.0.4):
    /// counters as `<name>_total`, gauges as-is, histograms with
    /// cumulative `_bucket{le=…}` series plus `_sum`/`_count`. Metric
    /// names are sanitized to `[a-zA-Z0-9_:]` (dots become underscores).
    pub fn render_prometheus(&self) -> String {
        let mut out = String::new();
        for (k, v) in self.counters.lock().unwrap().iter() {
            let name = promify(k);
            out.push_str(&format!("# TYPE {name}_total counter\n"));
            out.push_str(&format!("{name}_total {v}\n"));
        }
        for (k, v) in self.gauges.lock().unwrap().iter() {
            let name = promify(k);
            out.push_str(&format!("# TYPE {name} gauge\n"));
            out.push_str(&format!("{name} {v}\n"));
        }
        for (k, h) in self.histograms.lock().unwrap().iter() {
            if h.count() == 0 {
                continue;
            }
            let name = promify(k);
            out.push_str(&format!("# TYPE {name} histogram\n"));
            for (le, cum) in h.cumulative_buckets() {
                out.push_str(&format!("{name}_bucket{{le=\"{le:e}\"}} {cum}\n"));
            }
            out.push_str(&format!(
                "{name}_bucket{{le=\"+Inf\"}} {}\n",
                h.count()
            ));
            out.push_str(&format!("{name}_sum {}\n", h.sum()));
            out.push_str(&format!("{name}_count {}\n", h.count()));
        }
        out
    }

    /// Reset everything (tests).
    pub fn clear(&self) {
        self.counters.lock().unwrap().clear();
        self.histograms.lock().unwrap().clear();
        self.gauges.lock().unwrap().clear();
    }
}

/// Sanitize a metric name for Prometheus: every character outside
/// `[a-zA-Z0-9_:]` becomes `_`, and a leading digit gets a `_` prefix.
fn promify(name: &str) -> String {
    let mut s: String = name
        .chars()
        .map(|c| {
            if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
                c
            } else {
                '_'
            }
        })
        .collect();
    if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        s.insert(0, '_');
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let r = Registry::new();
        r.inc("a");
        r.inc("a");
        r.add("a", 3);
        assert_eq!(r.counter("a"), 5);
        assert_eq!(r.counter("missing"), 0);
    }

    #[test]
    fn samples_summarise() {
        let r = Registry::new();
        for i in 1..=5 {
            r.sample("lat", i as f64);
        }
        let s = r.summary("lat").unwrap();
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0); // exact
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        // quantiles are approximate: within the documented 5% bound
        assert!((s.median - 3.0).abs() <= 0.05 * 3.0, "median {}", s.median);
        assert!(r.summary("none").is_none());
    }

    #[test]
    fn gauges_last_write_wins() {
        let r = Registry::new();
        assert!(r.gauge("depth").is_none());
        r.set_gauge("depth", 4.0);
        r.set_gauge("depth", 7.0);
        assert_eq!(r.gauge("depth"), Some(7.0));
    }

    #[test]
    fn render_and_json() {
        let r = Registry::new();
        r.inc("calls");
        r.time("t", Duration::from_millis(5));
        r.set_gauge("g", 2.5);
        let text = r.render();
        assert!(text.contains("calls: 1"));
        assert!(text.contains("g: 2.500"));
        let j = r.to_json();
        assert!(j.get("counters").unwrap().get("calls").is_some());
        assert!(j.get("timers").unwrap().get("t").is_some());
        assert!(j.get("gauges").unwrap().get("g").is_some());
    }

    #[test]
    fn prometheus_exposition_is_well_formed() {
        let r = Registry::new();
        r.add("sweep.trials", 9);
        r.set_gauge("executor.queue_depth", 3.0);
        for i in 1..=100 {
            r.sample("service.http.request_seconds", i as f64 * 1e-3);
        }
        let text = r.render_prometheus();
        assert!(text.contains("# TYPE sweep_trials_total counter"));
        assert!(text.contains("sweep_trials_total 9"));
        assert!(text.contains("# TYPE executor_queue_depth gauge"));
        assert!(text.contains("executor_queue_depth 3"));
        assert!(text.contains("# TYPE service_http_request_seconds histogram"));
        assert!(text.contains("service_http_request_seconds_count 100"));
        assert!(text.contains("le=\"+Inf\"} 100"));
        // every bucket line has a le label and the series is cumulative
        let cums: Vec<u64> = text
            .lines()
            .filter(|l| l.starts_with("service_http_request_seconds_bucket"))
            .map(|l| l.rsplit(' ').next().unwrap().parse::<u64>().unwrap())
            .collect();
        assert!(cums.len() >= 2);
        assert!(cums.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*cums.last().unwrap(), 100);
    }

    #[test]
    fn concurrent_increments() {
        let r = Registry::new();
        std::thread::scope(|s| {
            for _ in 0..8 {
                s.spawn(|| {
                    for _ in 0..1000 {
                        r.inc("n");
                    }
                });
            }
        });
        assert_eq!(r.counter("n"), 8000);
    }
}
