//! Command-line argument parsing (offline substitute for `clap`).
//!
//! Supports the launcher grammar used by `containerstress`:
//!
//! ```text
//! containerstress <subcommand> [--flag] [--key value] [--key=value] [positional…]
//! ```
//!
//! Typed getters return `anyhow::Result` so the binary can print a friendly
//! usage message on bad input.

use std::collections::BTreeMap;

/// Parsed command line: one optional subcommand, `--key value` options,
/// bare `--flag`s and positionals, in original order.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First non-flag token, if any.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Bare tokens after the subcommand.
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        // subcommand = first non-flag token
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.subcommand = it.next();
            }
        }
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|nxt| !nxt.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Whether bare `--name` was passed.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Raw value of `--name value`, if present.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Raw value of `--name`, or `default`.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// `--name` as a usize, or `default` when absent.
    pub fn get_usize(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// `--name` as a float, or `default` when absent.
    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects a number, got '{v}'")),
        }
    }

    /// `--name` as a u64, or `default` when absent.
    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow::anyhow!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// Parse a comma-separated list of integers, e.g. `--signals 8,16,32`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim()
                        .parse()
                        .map_err(|_| anyhow::anyhow!("--{name}: bad integer '{s}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_options() {
        // NB: `--flag value` is parsed as an option; bare flags go last or
        // before another `--` token.
        let a = args("sweep --signals 8,16 --trials=5 out.csv --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("sweep"));
        assert_eq!(a.get("signals"), Some("8,16"));
        assert_eq!(a.get_usize("trials", 1).unwrap(), 5);
        assert!(a.flag("verbose"));
        assert_eq!(a.positional, vec!["out.csv"]);
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = args("scope --dry-run --fast");
        assert!(a.flag("dry-run"));
        assert!(a.flag("fast"));
        assert_eq!(a.get("dry-run"), None);
    }

    #[test]
    fn typed_errors() {
        let a = args("x --n abc");
        assert!(a.get_usize("n", 0).is_err());
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
    }

    #[test]
    fn usize_list() {
        let a = args("x --ms 32,64, 128");
        // note: space after comma splits the token; only '32,64,' belongs to --ms
        assert!(a.get_usize_list("ms", &[]).is_err());
        let b = args("x --ms 32,64,128");
        assert_eq!(b.get_usize_list("ms", &[]).unwrap(), vec![32, 64, 128]);
        assert_eq!(b.get_usize_list("none", &[1]).unwrap(), vec![1]);
    }

    #[test]
    fn no_subcommand_when_flag_first() {
        let a = args("--help");
        assert_eq!(a.subcommand, None);
        assert!(a.flag("help"));
    }
}
