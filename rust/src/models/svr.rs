//! SVM-family plug-in: auto-associative **kernel ridge regression** over
//! the selected memory vectors.
//!
//! The paper (§II.B) lists support vector machines among the pluggable ML
//! services. The least-squares SVM (a.k.a. kernel ridge regression) is the
//! standard dense-solver member of that family and shares MSET2's compute
//! skeleton — kernel matrix + regularised solve at training, kernel row +
//! weighted sum at streaming — which is exactly what ContainerStress needs
//! to scope: same cost *shape*, different constants and kernel.
//!
//! Model: `x̂ = Aᵀ k(x)` with `A = (K_DD + λI)⁻¹ D`, Gaussian kernel
//! `k(a,b) = exp(−‖a−b‖² / (2γ²n))`.

use super::PrognosticModel;
use crate::linalg::{kernel, reg_pinv, Mat, Workspace};
use crate::mset::{select_memory, Estimate, Scaler};

/// Least-squares SVM / kernel ridge auto-associative estimator.
pub struct SvrPlugin {
    /// Gaussian kernel width (dimensionless, scaled by √n like MSET's γ).
    pub gamma: f64,
    /// Ridge regularisation λ.
    pub lambda: f64,
    scaler: Option<Scaler>,
    /// Memory matrix (m × n, scaled units).
    d: Option<Mat>,
    /// Precomputed coefficient matrix `A = (K + λI)⁻¹ D` (m × n).
    a: Option<Mat>,
}

impl Default for SvrPlugin {
    fn default() -> Self {
        SvrPlugin {
            gamma: 1.0,
            lambda: 1e-3,
            scaler: None,
            d: None,
            a: None,
        }
    }
}

impl SvrPlugin {
    fn kernel(&self, a: &[f64], b: &[f64], n: usize) -> f64 {
        let mut d2 = 0.0;
        for (x, y) in a.iter().zip(b) {
            let d = x - y;
            d2 += d * d;
        }
        (-d2 / (2.0 * self.gamma * self.gamma * n as f64)).exp()
    }
}

impl PrognosticModel for SvrPlugin {
    fn name(&self) -> &'static str {
        "svr"
    }

    fn fit(&mut self, x_train: &Mat, m: usize) -> anyhow::Result<()> {
        anyhow::ensure!(m >= 2, "svr needs m ≥ 2 memory vectors");
        anyhow::ensure!(m <= x_train.rows, "m exceeds observations");
        let n = x_train.cols;
        let scaler = Scaler::fit(x_train);
        let xs = scaler.transform(x_train);
        let idx = select_memory(&xs, m);
        let mut d = Mat::zeros(m, n);
        for (r, &i) in idx.iter().enumerate() {
            d.row_mut(r).copy_from_slice(xs.row(i));
        }
        // K_DD + λI, then A = (K + λI)⁻¹ D
        let mut k = Mat::zeros(m, m);
        for i in 0..m {
            k[(i, i)] = 1.0 + self.lambda;
            for j in 0..i {
                let v = self.kernel(d.row(i), d.row(j), n);
                k[(i, j)] = v;
                k[(j, i)] = v;
            }
        }
        let kinv = reg_pinv(&k, 0.0);
        self.a = Some(kinv.matmul(&d));
        self.d = Some(d);
        self.scaler = Some(scaler);
        Ok(())
    }

    fn estimate(&self, x: &Mat) -> Estimate {
        let d = self.d.as_ref().expect("fit first");
        let a = self.a.as_ref().unwrap();
        Workspace::with(|ws| {
            let mut xs = Mat {
                rows: 0,
                cols: 0,
                data: ws.take_f64(0),
            };
            self.scaler.as_ref().unwrap().transform_into(x, &mut xs);
            let n = xs.cols;
            // Kernel rows k(x_r, D) over the blocked squared-distance
            // core (Gram expansion), then x̂ = K·A as one blocked
            // product — same shape as the MSET surveillance pipeline.
            let mut kx = Mat {
                rows: 0,
                cols: 0,
                data: ws.take_f64(0),
            };
            kernel::dist2_cross_into(&mut kx, &xs, d, ws);
            let denom = 2.0 * self.gamma * self.gamma * n as f64;
            for v in kx.data.iter_mut() {
                *v = (-*v / denom).exp();
            }
            let mut xhat = Mat::zeros(0, 0);
            kernel::matmul_into(&mut xhat, &kx, a, ws);
            let resid = xs.sub(&xhat);
            ws.give_f64(kx.data);
            ws.give_f64(xs.data);
            Estimate { xhat, resid }
        })
    }

    fn train_flops(&self, n: usize, m: usize) -> f64 {
        let (n, m) = (n as f64, m as f64);
        // kernel matrix 3nm²/2 + inverse 11m³ + A = K⁻¹D 2m²n
        1.5 * n * m * m + 11.0 * m * m * m + 2.0 * m * m * n
    }

    fn surveil_flops_per_obs(&self, n: usize, m: usize) -> f64 {
        let (n, m) = (n as f64, m as f64);
        3.0 * n * m + 2.0 * m * n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpss::{inject, synthesize, Fault, TpssConfig};

    fn fitted(seed: u64) -> (SvrPlugin, TpssConfig) {
        let cfg = TpssConfig {
            n_signals: 5,
            n_obs: 1500,
            cross_corr: 0.6,
            ..TpssConfig::default()
        };
        let train = synthesize(&cfg, seed);
        let mut svr = SvrPlugin::default();
        svr.fit(&train.data, 64).unwrap();
        (svr, cfg)
    }

    #[test]
    fn memory_vectors_reconstruct() {
        let (svr, _) = fitted(1);
        let d_raw = svr.scaler.as_ref().unwrap().inverse(svr.d.as_ref().unwrap());
        let est = svr.estimate(&d_raw);
        let max_resid = est.resid.data.iter().fold(0.0f64, |a, &b| a.max(b.abs()));
        assert!(max_resid < 0.1, "max memory-vector residual {max_resid}");
    }

    #[test]
    fn healthy_vs_faulted_residuals() {
        let (svr, cfg) = fitted(2);
        let probe_cfg = TpssConfig { n_obs: 300, ..cfg };
        let healthy = synthesize(&probe_cfg, 3);
        let mut faulted = synthesize(&probe_cfg, 3);
        inject(&mut faulted, 1, Fault::Step { magnitude: 8.0 }, 0.0, 4);
        let rh = svr.estimate(&healthy.data).resid.norm();
        let rf = svr.estimate(&faulted.data).resid.norm();
        assert!(rf > 1.5 * rh, "fault {rf} vs healthy {rh}");
    }

    #[test]
    fn rejects_bad_m() {
        let cfg = TpssConfig::sized(4, 100);
        let train = synthesize(&cfg, 5);
        let mut svr = SvrPlugin::default();
        assert!(svr.fit(&train.data, 1).is_err());
        assert!(svr.fit(&train.data, 500).is_err());
    }

    #[test]
    fn flop_model_monotone() {
        let p = SvrPlugin::default();
        assert!(p.train_flops(16, 128) > p.train_flops(8, 64));
        assert!(p.surveil_flops_per_obs(16, 128) > p.surveil_flops_per_obs(8, 64));
    }
}
