//! Runtime-dispatched explicit-SIMD tier for the [`kernel`](super::kernel)
//! hot loops: AVX2+FMA (`std::arch::x86_64`) and NEON
//! (`std::arch::aarch64`) micro-kernels behind a one-time dispatch
//! decision, with the scalar blocked tier as the bit-identical default.
//!
//! ## Dispatch
//!
//! The active backend is a process-wide decision cached in an atomic.
//! Precedence (highest first):
//!
//! 1. [`install`] — called by `main` for the `--kernel-backend` flag /
//!    `kernel_backend` config key (source `"config"`), or by tests;
//! 2. the `CONTAINERSTRESS_KERNEL` env knob ([`ENV_KNOB`]), read lazily
//!    on the first [`active`] call (source `"env"`);
//! 3. the default: scalar (source `"default"`).
//!
//! Requests are `scalar` (force the exact tier), `simd` (force the
//! vector tier; [`install`] errors with [`SimdUnavailable`] if the host
//! has neither AVX2+FMA nor NEON), or `auto` (vector tier if available,
//! scalar otherwise). The decision plus its provenance is readable via
//! [`dispatch_info`] and surfaced in `/healthz` and `/metrics`.
//!
//! ## Tolerance mode, and what stays exact
//!
//! The SIMD tier computes every dot product as `LANES` independent lane
//! partial sums (FMA-contracted), horizontally reduced in a fixed order,
//! plus an ordered `mul_add` scalar tail — a *different* op sequence from
//! the scalar tier's single ascending-`k` accumulator, so SIMD results
//! agree with the naive reference only to a documented tolerance
//! (≤ 1e-10 across the property-test shapes; see `tests/simd_props.rs`).
//!
//! Crucially, the SIMD tier is *internally* bit-consistent: every output
//! element — full register tile, edge row, `syrk` diagonal crossing, or
//! `row_norms2` entry — is produced by the **same** lane-partition +
//! horizontal-sum + tail sequence. So the cross-kernel exact invariants
//! the rest of the crate relies on survive under SIMD:
//!
//! - `dist2_sym` equals `dist2_cross(a, a)` bit for bit (norms read off
//!   the Gram diagonal perform the same op sequence as the norm pass);
//! - `sim_cross(d, d)` equals `sim_matrix(d)` bit for bit;
//! - diagonal distances are exactly `0.0` (`x + x − 2x ≡ 0`).
//!
//! What does *not* survive: bit-identity with the scalar/naive reference,
//! and bit-exactness under `k` zero-padding (padding changes the lane
//! partition). Anything that depends on those — trial seeds, cached
//! sweep cells, the exhaustive paper schedules — must run the scalar
//! default, which is why SIMD is strictly opt-in.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::Mutex;

/// Environment knob consulted on first use when no explicit [`install`]
/// has happened: `scalar` | `simd` | `auto`.
pub const ENV_KNOB: &str = "CONTAINERSTRESS_KERNEL";

/// What the user asked for (flag, config key, env knob, or default).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendRequest {
    /// Force the scalar blocked tier (bit-identical to the naive
    /// reference; the default).
    Scalar,
    /// Force the vector tier; an error if the host supports none.
    Simd,
    /// Vector tier when available, scalar otherwise.
    Auto,
}

impl BackendRequest {
    /// Parse a knob value (case-insensitive, surrounding whitespace
    /// ignored). Returns `None` for anything but `scalar`/`simd`/`auto`.
    pub fn parse(s: &str) -> Option<Self> {
        match s.trim().to_ascii_lowercase().as_str() {
            "scalar" => Some(Self::Scalar),
            "simd" => Some(Self::Simd),
            "auto" => Some(Self::Auto),
            _ => None,
        }
    }

    /// Canonical knob spelling.
    pub fn as_str(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Simd => "simd",
            Self::Auto => "auto",
        }
    }
}

/// The tier actually executing kernel calls after dispatch.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ActiveBackend {
    /// Scalar blocked kernels (exact mode).
    Scalar,
    /// AVX2 + FMA micro-kernels (tolerance mode), x86-64 only.
    Avx2Fma,
    /// NEON micro-kernels (tolerance mode), aarch64 only.
    Neon,
}

impl ActiveBackend {
    /// Stable ISA label used in bench rows, `/healthz`, and metrics.
    pub fn isa(self) -> &'static str {
        match self {
            Self::Scalar => "scalar",
            Self::Avx2Fma => "avx2_fma",
            Self::Neon => "neon",
        }
    }

    /// Whether this is a vector tier (tolerance mode).
    pub fn is_simd(self) -> bool {
        !matches!(self, Self::Scalar)
    }

    /// Numerical contract label: `"exact"` (bit-identical to the naive
    /// reference) or `"tolerance"` (≤ 1e-10 agreement; see module docs).
    pub fn mode(self) -> &'static str {
        if self.is_simd() {
            "tolerance"
        } else {
            "exact"
        }
    }
}

/// The dispatch decision plus its provenance, for `/healthz` reporting.
#[derive(Debug, Clone, Copy)]
pub struct DispatchInfo {
    /// What was requested.
    pub requested: BackendRequest,
    /// Where the request came from: `"config"`, `"env"`, `"default"`,
    /// `"env-fallback"` (env asked for `simd` on a host without it), or
    /// a test/bench-supplied label.
    pub source: &'static str,
    /// The tier that actually runs.
    pub active: ActiveBackend,
}

/// Error returned by [`install`] when `simd` is explicitly requested but
/// no vector tier exists for this host.
#[derive(Debug, Clone, Copy, thiserror::Error)]
#[error("no SIMD kernel tier available on this host (need AVX2+FMA on x86_64 or NEON on aarch64)")]
pub struct SimdUnavailable;

// 0 = not yet decided, then 1 + ActiveBackend discriminant.
static ACTIVE: AtomicU8 = AtomicU8::new(0);
static INFO: Mutex<Option<DispatchInfo>> = Mutex::new(None);

fn code(b: ActiveBackend) -> u8 {
    match b {
        ActiveBackend::Scalar => 1,
        ActiveBackend::Avx2Fma => 2,
        ActiveBackend::Neon => 3,
    }
}

/// Probe the host for a vector tier: AVX2+FMA on x86-64 (runtime CPUID
/// check), NEON on aarch64 (baseline, always present), `None` elsewhere.
pub fn detect() -> Option<ActiveBackend> {
    #[cfg(target_arch = "x86_64")]
    {
        if std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
        {
            Some(ActiveBackend::Avx2Fma)
        } else {
            None
        }
    }
    #[cfg(target_arch = "aarch64")]
    {
        Some(ActiveBackend::Neon)
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    {
        None
    }
}

/// Resolve a request against the host: `Scalar` always succeeds, `Simd`
/// requires a detected tier, `Auto` degrades to scalar.
pub fn resolve(req: BackendRequest) -> Result<ActiveBackend, SimdUnavailable> {
    match req {
        BackendRequest::Scalar => Ok(ActiveBackend::Scalar),
        BackendRequest::Simd => detect().ok_or(SimdUnavailable),
        BackendRequest::Auto => Ok(detect().unwrap_or(ActiveBackend::Scalar)),
    }
}

/// Install a dispatch decision process-wide (overrides any earlier one).
/// `source` labels the provenance for [`dispatch_info`].
pub fn install(req: BackendRequest, source: &'static str) -> Result<DispatchInfo, SimdUnavailable> {
    let active = resolve(req)?;
    let info = DispatchInfo {
        requested: req,
        source,
        active,
    };
    *INFO.lock().unwrap() = Some(info);
    ACTIVE.store(code(active), Ordering::Release);
    Ok(info)
}

/// The currently active backend. On first call without a prior
/// [`install`], reads [`ENV_KNOB`] and caches the decision; afterwards
/// this is a single atomic load (safe for the kernel hot path).
pub fn active() -> ActiveBackend {
    match ACTIVE.load(Ordering::Acquire) {
        1 => ActiveBackend::Scalar,
        2 => ActiveBackend::Avx2Fma,
        3 => ActiveBackend::Neon,
        _ => init_from_env(),
    }
}

/// Force the env-knob initialisation path (normally triggered lazily by
/// the first [`active`] call). Invalid values and `simd` requests on
/// hosts without a vector tier degrade to scalar with a logged warning —
/// a service must come up even if the knob is wrong.
pub fn init_from_env() -> ActiveBackend {
    let (req, source) = match std::env::var(ENV_KNOB) {
        Ok(v) if !v.trim().is_empty() => match BackendRequest::parse(&v) {
            Some(r) => (r, "env"),
            None => {
                log::warn!("{ENV_KNOB}={v:?} is not one of scalar|simd|auto; using scalar");
                (BackendRequest::Scalar, "default")
            }
        },
        _ => (BackendRequest::Scalar, "default"),
    };
    match install(req, source) {
        Ok(info) => info.active,
        Err(SimdUnavailable) => {
            log::warn!("{ENV_KNOB}=simd requested but this host has no SIMD tier; using scalar");
            install(BackendRequest::Scalar, "env-fallback")
                .expect("scalar install cannot fail")
                .active
        }
    }
}

/// The dispatch decision plus provenance (initialising from the env on
/// first use, like [`active`]).
pub fn dispatch_info() -> DispatchInfo {
    let _ = active();
    INFO.lock()
        .unwrap()
        .expect("dispatch info recorded by install()")
}

/// Clear the cached dispatch decision so the next [`active`] call
/// re-runs [`init_from_env`]. Escape hatch for the dispatch-roundtrip
/// tests; production code never calls this.
pub fn reset_for_tests() {
    *INFO.lock().unwrap() = None;
    ACTIVE.store(0, Ordering::Release);
}

// ---------------------------------------------------------------------------
// Safe dispatchers. Each takes the backend explicitly so tests and benches
// can compare tiers directly without touching the process-wide decision;
// `kernel.rs` passes `active()`. The `_ =>` arms are the scalar fallback
// (single-accumulator naive dots) so every dispatcher is total on every
// target — `kernel.rs` only routes here when `is_simd()`, so the fallback
// is exercised by tests, not the production scalar path.
// ---------------------------------------------------------------------------

/// `out[m×n] = A[m×k]·B[n×k]ᵀ`, row-major, via the active tier's
/// micro-kernel (4-row × 2-column register tiles of `LANES`-wide FMA
/// chains; edge rows/columns use the same vector dot per element).
#[allow(clippy::too_many_arguments)]
pub fn gemm_nt(
    out: &mut [f64],
    a: &[f64],
    b: &[f64],
    m: usize,
    n: usize,
    k: usize,
    backend: ActiveBackend,
) {
    assert_eq!(a.len(), m * k, "simd gemm_nt: A buffer size");
    assert_eq!(b.len(), n * k, "simd gemm_nt: B buffer size");
    assert_eq!(out.len(), m * n, "simd gemm_nt: C buffer size");
    if k == 0 {
        out.fill(0.0);
        return;
    }
    match backend {
        #[cfg(target_arch = "x86_64")]
        ActiveBackend::Avx2Fma => unsafe { avx2::gemm_nt(out, a, b, m, n, k) },
        #[cfg(target_arch = "aarch64")]
        ActiveBackend::Neon => unsafe { neon::gemm_nt(out, a, b, m, n, k) },
        _ => {
            for i in 0..m {
                let ar = &a[i * k..][..k];
                for j in 0..n {
                    let br = &b[j * k..][..k];
                    out[i * n + j] = scalar_dot(ar, br);
                }
            }
        }
    }
}

/// Lower triangle (inclusive diagonal) of `A·Aᵀ` (`A: m×k`) into `out`
/// (`m×m`); entries strictly above the diagonal are left untouched — the
/// caller mirrors. Diagonal entries perform the exact op sequence of
/// [`row_norms2`], so norms can be read off the Gram diagonal bit-safely.
pub fn syrk_lower(out: &mut [f64], a: &[f64], m: usize, k: usize, backend: ActiveBackend) {
    assert_eq!(a.len(), m * k, "simd syrk_lower: A buffer size");
    assert_eq!(out.len(), m * m, "simd syrk_lower: C buffer size");
    if k == 0 {
        out.fill(0.0);
        return;
    }
    match backend {
        #[cfg(target_arch = "x86_64")]
        ActiveBackend::Avx2Fma => unsafe { avx2::syrk_lower(out, a, m, k) },
        #[cfg(target_arch = "aarch64")]
        ActiveBackend::Neon => unsafe { neon::syrk_lower(out, a, m, k) },
        _ => {
            for r in 0..m {
                let ar = &a[r * k..][..k];
                for s in 0..=r {
                    out[r * m + s] = scalar_dot(ar, &a[s * k..][..k]);
                }
            }
        }
    }
}

/// Per-row squared norms `out[i] = ‖row_i‖²` over a `rows×cols`
/// row-major buffer — the same vector dot as the [`syrk_lower`] diagonal.
pub fn row_norms2(a: &[f64], rows: usize, cols: usize, out: &mut [f64], backend: ActiveBackend) {
    assert_eq!(a.len(), rows * cols, "simd row_norms2: input size");
    assert_eq!(out.len(), rows, "simd row_norms2: output size");
    if cols == 0 {
        out.fill(0.0);
        return;
    }
    match backend {
        #[cfg(target_arch = "x86_64")]
        ActiveBackend::Avx2Fma => unsafe { avx2::row_norms2(a, cols, out) },
        #[cfg(target_arch = "aarch64")]
        ActiveBackend::Neon => unsafe { neon::row_norms2(a, cols, out) },
        _ => {
            for (o, row) in out.iter_mut().zip(a.chunks_exact(cols)) {
                *o = scalar_dot(row, row);
            }
        }
    }
}

/// Fused squared-distance epilogue over one Gram row:
/// `row[j] = max(nai + nb[j] − 2·row[j], 0)`. Add/sub/mul are exact IEEE
/// ops in the same order as the scalar epilogue, so this is bit-identical
/// to it — only the dot products upstream are in tolerance mode.
pub fn dist2_epilogue(row: &mut [f64], nai: f64, nb: &[f64], backend: ActiveBackend) {
    assert_eq!(row.len(), nb.len(), "simd dist2_epilogue: row/norm size");
    match backend {
        #[cfg(target_arch = "x86_64")]
        ActiveBackend::Avx2Fma => unsafe { avx2::dist2_epilogue(row, nai, nb) },
        #[cfg(target_arch = "aarch64")]
        ActiveBackend::Neon => unsafe { neon::dist2_epilogue(row, nai, nb) },
        _ => {
            for (v, &nbj) in row.iter_mut().zip(nb.iter()) {
                *v = (nai + nbj - 2.0 * *v).max(0.0);
            }
        }
    }
}

/// Ascending-order single-accumulator dot — the scalar fallback's (and
/// the scalar tier's) op sequence.
fn scalar_dot(a: &[f64], b: &[f64]) -> f64 {
    let mut acc = 0.0;
    for (x, y) in a.iter().zip(b.iter()) {
        acc += x * y;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! AVX2+FMA micro-kernels. Every output element is the identical
    //! sequence: 4 lane partial sums over `k & !3` (FMA), horizontal
    //! reduction `(l0+l2)+(l1+l3)`, then an ordered `mul_add` tail.
    use core::arch::x86_64::*;

    const LANES: usize = 4;

    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn hsum(v: __m256d) -> f64 {
        let lo = _mm256_castpd256_pd128(v); // l0, l1
        let hi = _mm256_extractf128_pd::<1>(v); // l2, l3
        let s = _mm_add_pd(lo, hi); // l0+l2, l1+l3
        _mm_cvtsd_f64(_mm_add_sd(s, _mm_unpackhi_pd(s, s)))
    }

    /// The canonical vector dot: lane partials + fixed hsum + ordered
    /// scalar tail. Every other element producer matches this bitwise.
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn dot(a: *const f64, b: *const f64, k: usize) -> f64 {
        let kv = k & !(LANES - 1);
        let mut acc = _mm256_setzero_pd();
        let mut t = 0;
        while t < kv {
            acc = _mm256_fmadd_pd(_mm256_loadu_pd(a.add(t)), _mm256_loadu_pd(b.add(t)), acc);
            t += LANES;
        }
        let mut s = hsum(acc);
        while t < k {
            s = (*a.add(t)).mul_add(*b.add(t), s);
            t += 1;
        }
        s
    }

    /// 4 A-rows × 2 B-rows register tile: 8 independent FMA accumulator
    /// chains (throughput-bound, unlike a lone latency-bound dot). Each
    /// element finishes with the same hsum + tail as [`dot`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn tile4x2(
        out: *mut f64,
        ld: usize,
        a: *const f64,
        b: *const f64,
        k: usize,
        i0: usize,
        j0: usize,
    ) {
        let a0 = a.add(i0 * k);
        let a1 = a.add((i0 + 1) * k);
        let a2 = a.add((i0 + 2) * k);
        let a3 = a.add((i0 + 3) * k);
        let b0 = b.add(j0 * k);
        let b1 = b.add((j0 + 1) * k);
        let kv = k & !(LANES - 1);
        let mut c00 = _mm256_setzero_pd();
        let mut c01 = _mm256_setzero_pd();
        let mut c10 = _mm256_setzero_pd();
        let mut c11 = _mm256_setzero_pd();
        let mut c20 = _mm256_setzero_pd();
        let mut c21 = _mm256_setzero_pd();
        let mut c30 = _mm256_setzero_pd();
        let mut c31 = _mm256_setzero_pd();
        let mut t = 0;
        while t < kv {
            let bv0 = _mm256_loadu_pd(b0.add(t));
            let bv1 = _mm256_loadu_pd(b1.add(t));
            let av0 = _mm256_loadu_pd(a0.add(t));
            c00 = _mm256_fmadd_pd(av0, bv0, c00);
            c01 = _mm256_fmadd_pd(av0, bv1, c01);
            let av1 = _mm256_loadu_pd(a1.add(t));
            c10 = _mm256_fmadd_pd(av1, bv0, c10);
            c11 = _mm256_fmadd_pd(av1, bv1, c11);
            let av2 = _mm256_loadu_pd(a2.add(t));
            c20 = _mm256_fmadd_pd(av2, bv0, c20);
            c21 = _mm256_fmadd_pd(av2, bv1, c21);
            let av3 = _mm256_loadu_pd(a3.add(t));
            c30 = _mm256_fmadd_pd(av3, bv0, c30);
            c31 = _mm256_fmadd_pd(av3, bv1, c31);
            t += LANES;
        }
        let rows = [a0, a1, a2, a3];
        let cols = [b0, b1];
        let accs = [[c00, c01], [c10, c11], [c20, c21], [c30, c31]];
        for (r, (ar, cr)) in rows.iter().zip(accs.iter()).enumerate() {
            for (c, (bc, acc)) in cols.iter().zip(cr.iter()).enumerate() {
                let mut s = hsum(*acc);
                let mut u = kv;
                while u < k {
                    s = (*ar.add(u)).mul_add(*bc.add(u), s);
                    u += 1;
                }
                *out.add((i0 + r) * ld + j0 + c) = s;
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn gemm_nt(out: &mut [f64], a: &[f64], b: &[f64], m: usize, n: usize, k: usize) {
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let op = out.as_mut_ptr();
        let mut i0 = 0;
        while i0 + 4 <= m {
            let mut j0 = 0;
            while j0 + 2 <= n {
                tile4x2(op, n, ap, bp, k, i0, j0);
                j0 += 2;
            }
            while j0 < n {
                for r in 0..4 {
                    *op.add((i0 + r) * n + j0) = dot(ap.add((i0 + r) * k), bp.add(j0 * k), k);
                }
                j0 += 1;
            }
            i0 += 4;
        }
        while i0 < m {
            for j in 0..n {
                *op.add(i0 * n + j) = dot(ap.add(i0 * k), bp.add(j * k), k);
            }
            i0 += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn syrk_lower(out: &mut [f64], a: &[f64], m: usize, k: usize) {
        let ap = a.as_ptr();
        let op = out.as_mut_ptr();
        let mut i0 = 0;
        while i0 + 4 <= m {
            let mut j0 = 0;
            // full tile strictly in the lower triangle: both columns
            // (j0, j0+1) at or below the tile's topmost row i0
            while j0 < i0 {
                tile4x2(op, m, ap, ap, k, i0, j0);
                j0 += 2;
            }
            // diagonal-crossing remainder: per-element vector dots
            for r in i0..i0 + 4 {
                for s in j0..=r {
                    *op.add(r * m + s) = dot(ap.add(r * k), ap.add(s * k), k);
                }
            }
            i0 += 4;
        }
        for r in i0..m {
            for s in 0..=r {
                *op.add(r * m + s) = dot(ap.add(r * k), ap.add(s * k), k);
            }
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn row_norms2(a: &[f64], cols: usize, out: &mut [f64]) {
        let ap = a.as_ptr();
        for (i, o) in out.iter_mut().enumerate() {
            let r = ap.add(i * cols);
            *o = dot(r, r, cols);
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dist2_epilogue(row: &mut [f64], nai: f64, nb: &[f64]) {
        let n = row.len();
        let nv = n & !(LANES - 1);
        let na = _mm256_set1_pd(nai);
        let two = _mm256_set1_pd(2.0);
        let zero = _mm256_setzero_pd();
        let rp = row.as_mut_ptr();
        let nbp = nb.as_ptr();
        let mut j = 0;
        while j < nv {
            let v = _mm256_loadu_pd(rp.add(j));
            let nbv = _mm256_loadu_pd(nbp.add(j));
            let x = _mm256_sub_pd(_mm256_add_pd(na, nbv), _mm256_mul_pd(two, v));
            _mm256_storeu_pd(rp.add(j), _mm256_max_pd(x, zero));
            j += LANES;
        }
        while j < n {
            let v = *rp.add(j);
            *rp.add(j) = (nai + *nbp.add(j) - 2.0 * v).max(0.0);
            j += 1;
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod neon {
    //! NEON micro-kernels — same structure as the AVX2 module with
    //! 2-wide lanes: partial sums over `k & !1` (`vfmaq_f64`), horizontal
    //! reduction `lane0 + lane1`, ordered `mul_add` tail.
    use core::arch::aarch64::*;

    const LANES: usize = 2;

    #[inline]
    #[target_feature(enable = "neon")]
    unsafe fn hsum(v: float64x2_t) -> f64 {
        vgetq_lane_f64::<0>(v) + vgetq_lane_f64::<1>(v)
    }

    /// The canonical vector dot; see the AVX2 twin for the contract.
    #[target_feature(enable = "neon")]
    unsafe fn dot(a: *const f64, b: *const f64, k: usize) -> f64 {
        let kv = k & !(LANES - 1);
        let mut acc = vdupq_n_f64(0.0);
        let mut t = 0;
        while t < kv {
            acc = vfmaq_f64(acc, vld1q_f64(a.add(t)), vld1q_f64(b.add(t)));
            t += LANES;
        }
        let mut s = hsum(acc);
        while t < k {
            s = (*a.add(t)).mul_add(*b.add(t), s);
            t += 1;
        }
        s
    }

    /// 4×2 register tile, 8 independent FMA chains; elements finish with
    /// the same hsum + tail as [`dot`].
    #[allow(clippy::too_many_arguments)]
    #[target_feature(enable = "neon")]
    unsafe fn tile4x2(
        out: *mut f64,
        ld: usize,
        a: *const f64,
        b: *const f64,
        k: usize,
        i0: usize,
        j0: usize,
    ) {
        let a0 = a.add(i0 * k);
        let a1 = a.add((i0 + 1) * k);
        let a2 = a.add((i0 + 2) * k);
        let a3 = a.add((i0 + 3) * k);
        let b0 = b.add(j0 * k);
        let b1 = b.add((j0 + 1) * k);
        let kv = k & !(LANES - 1);
        let mut c00 = vdupq_n_f64(0.0);
        let mut c01 = vdupq_n_f64(0.0);
        let mut c10 = vdupq_n_f64(0.0);
        let mut c11 = vdupq_n_f64(0.0);
        let mut c20 = vdupq_n_f64(0.0);
        let mut c21 = vdupq_n_f64(0.0);
        let mut c30 = vdupq_n_f64(0.0);
        let mut c31 = vdupq_n_f64(0.0);
        let mut t = 0;
        while t < kv {
            let bv0 = vld1q_f64(b0.add(t));
            let bv1 = vld1q_f64(b1.add(t));
            let av0 = vld1q_f64(a0.add(t));
            c00 = vfmaq_f64(c00, av0, bv0);
            c01 = vfmaq_f64(c01, av0, bv1);
            let av1 = vld1q_f64(a1.add(t));
            c10 = vfmaq_f64(c10, av1, bv0);
            c11 = vfmaq_f64(c11, av1, bv1);
            let av2 = vld1q_f64(a2.add(t));
            c20 = vfmaq_f64(c20, av2, bv0);
            c21 = vfmaq_f64(c21, av2, bv1);
            let av3 = vld1q_f64(a3.add(t));
            c30 = vfmaq_f64(c30, av3, bv0);
            c31 = vfmaq_f64(c31, av3, bv1);
            t += LANES;
        }
        let rows = [a0, a1, a2, a3];
        let cols = [b0, b1];
        let accs = [[c00, c01], [c10, c11], [c20, c21], [c30, c31]];
        for (r, (ar, cr)) in rows.iter().zip(accs.iter()).enumerate() {
            for (c, (bc, acc)) in cols.iter().zip(cr.iter()).enumerate() {
                let mut s = hsum(*acc);
                let mut u = kv;
                while u < k {
                    s = (*ar.add(u)).mul_add(*bc.add(u), s);
                    u += 1;
                }
                *out.add((i0 + r) * ld + j0 + c) = s;
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn gemm_nt(out: &mut [f64], a: &[f64], b: &[f64], m: usize, n: usize, k: usize) {
        let ap = a.as_ptr();
        let bp = b.as_ptr();
        let op = out.as_mut_ptr();
        let mut i0 = 0;
        while i0 + 4 <= m {
            let mut j0 = 0;
            while j0 + 2 <= n {
                tile4x2(op, n, ap, bp, k, i0, j0);
                j0 += 2;
            }
            while j0 < n {
                for r in 0..4 {
                    *op.add((i0 + r) * n + j0) = dot(ap.add((i0 + r) * k), bp.add(j0 * k), k);
                }
                j0 += 1;
            }
            i0 += 4;
        }
        while i0 < m {
            for j in 0..n {
                *op.add(i0 * n + j) = dot(ap.add(i0 * k), bp.add(j * k), k);
            }
            i0 += 1;
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn syrk_lower(out: &mut [f64], a: &[f64], m: usize, k: usize) {
        let ap = a.as_ptr();
        let op = out.as_mut_ptr();
        let mut i0 = 0;
        while i0 + 4 <= m {
            let mut j0 = 0;
            while j0 < i0 {
                tile4x2(op, m, ap, ap, k, i0, j0);
                j0 += 2;
            }
            for r in i0..i0 + 4 {
                for s in j0..=r {
                    *op.add(r * m + s) = dot(ap.add(r * k), ap.add(s * k), k);
                }
            }
            i0 += 4;
        }
        for r in i0..m {
            for s in 0..=r {
                *op.add(r * m + s) = dot(ap.add(r * k), ap.add(s * k), k);
            }
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn row_norms2(a: &[f64], cols: usize, out: &mut [f64]) {
        let ap = a.as_ptr();
        for (i, o) in out.iter_mut().enumerate() {
            let r = ap.add(i * cols);
            *o = dot(r, r, cols);
        }
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dist2_epilogue(row: &mut [f64], nai: f64, nb: &[f64]) {
        let n = row.len();
        let nv = n & !(LANES - 1);
        let na = vdupq_n_f64(nai);
        let two = vdupq_n_f64(2.0);
        let zero = vdupq_n_f64(0.0);
        let rp = row.as_mut_ptr();
        let nbp = nb.as_ptr();
        let mut j = 0;
        while j < nv {
            let v = vld1q_f64(rp.add(j));
            let nbv = vld1q_f64(nbp.add(j));
            let x = vsubq_f64(vaddq_f64(na, nbv), vmulq_f64(two, v));
            vst1q_f64(rp.add(j), vmaxnmq_f64(x, zero));
            j += LANES;
        }
        while j < n {
            let v = *rp.add(j);
            *rp.add(j) = (nai + *nbp.add(j) - 2.0 * v).max(0.0);
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    // Direct-call tests only: nothing here mutates the process-wide
    // dispatch, so this module is safe to run in the multi-threaded test
    // binary. Global-flip coverage lives in `tests/simd_props.rs`.
    use super::*;
    use crate::util::rng::Rng;

    fn randv(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::new(seed);
        let mut v = vec![0.0; n];
        rng.fill_gauss(&mut v);
        v
    }

    #[test]
    fn request_parse_roundtrip() {
        for req in [
            BackendRequest::Scalar,
            BackendRequest::Simd,
            BackendRequest::Auto,
        ] {
            assert_eq!(BackendRequest::parse(req.as_str()), Some(req));
            assert_eq!(
                BackendRequest::parse(&format!("  {}  ", req.as_str().to_uppercase())),
                Some(req)
            );
        }
        assert_eq!(BackendRequest::parse("warp"), None);
        assert_eq!(BackendRequest::parse(""), None);
    }

    #[test]
    fn backend_labels_are_consistent() {
        assert_eq!(ActiveBackend::Scalar.mode(), "exact");
        assert!(!ActiveBackend::Scalar.is_simd());
        for b in [ActiveBackend::Avx2Fma, ActiveBackend::Neon] {
            assert!(b.is_simd());
            assert_eq!(b.mode(), "tolerance");
        }
        assert_eq!(ActiveBackend::Avx2Fma.isa(), "avx2_fma");
        assert_eq!(ActiveBackend::Neon.isa(), "neon");
    }

    #[test]
    fn resolve_honours_detection() {
        assert_eq!(
            resolve(BackendRequest::Scalar).unwrap(),
            ActiveBackend::Scalar
        );
        match detect() {
            Some(b) => {
                assert!(b.is_simd());
                assert_eq!(resolve(BackendRequest::Simd).unwrap(), b);
                assert_eq!(resolve(BackendRequest::Auto).unwrap(), b);
            }
            None => {
                assert!(resolve(BackendRequest::Simd).is_err());
                assert_eq!(
                    resolve(BackendRequest::Auto).unwrap(),
                    ActiveBackend::Scalar
                );
            }
        }
    }

    #[test]
    fn scalar_fallback_dispatch_matches_naive() {
        let (m, n, k) = (5, 3, 7);
        let a = randv(m * k, 1);
        let b = randv(n * k, 2);
        let mut out = vec![0.0; m * n];
        gemm_nt(&mut out, &a, &b, m, n, k, ActiveBackend::Scalar);
        for i in 0..m {
            for j in 0..n {
                assert_eq!(out[i * n + j], scalar_dot(&a[i * k..][..k], &b[j * k..][..k]));
            }
        }
    }

    #[test]
    fn simd_gemm_close_to_scalar_and_tile_matches_dot_bitwise() {
        let Some(be) = detect() else { return };
        // m=9, n=5 exercises full 4×2 tiles, the odd column, and edge rows
        let (m, n, k) = (9, 5, 23);
        let a = randv(m * k, 3);
        let b = randv(n * k, 4);
        let mut out = vec![0.0; m * n];
        gemm_nt(&mut out, &a, &b, m, n, k, be);
        let mut sc = vec![0.0; m * n];
        gemm_nt(&mut sc, &a, &b, m, n, k, ActiveBackend::Scalar);
        for (x, y) in out.iter().zip(sc.iter()) {
            assert!((x - y).abs() <= 1e-10, "tolerance-mode bound: {x} vs {y}");
        }
        // internal bit-consistency: every tile element equals the 1×1
        // (pure-dot) path bitwise
        for i in 0..m {
            for j in 0..n {
                let mut one = [0.0];
                gemm_nt(&mut one, &a[i * k..][..k], &b[j * k..][..k], 1, 1, k, be);
                assert_eq!(
                    out[i * n + j].to_bits(),
                    one[0].to_bits(),
                    "tile/edge element ({i},{j}) must match the vector dot bitwise"
                );
            }
        }
    }

    #[test]
    fn simd_syrk_diag_matches_row_norms_bitwise() {
        let Some(be) = detect() else { return };
        let (m, k) = (11, 17);
        let a = randv(m * k, 5);
        let mut gram = vec![0.0; m * m];
        syrk_lower(&mut gram, &a, m, k, be);
        let mut norms = vec![0.0; m];
        row_norms2(&a, m, k, &mut norms, be);
        for i in 0..m {
            assert_eq!(gram[i * m + i].to_bits(), norms[i].to_bits());
        }
        // lower triangle agrees with the full gemm (same dot sequence)
        let mut full = vec![0.0; m * m];
        gemm_nt(&mut full, &a, &a, m, m, k, be);
        for i in 0..m {
            for j in 0..=i {
                assert_eq!(gram[i * m + j].to_bits(), full[i * m + j].to_bits());
            }
        }
    }

    #[test]
    fn dist2_epilogue_bit_identical_across_backends() {
        let nb = randv(7, 6).iter().map(|v| v * v).collect::<Vec<_>>();
        let base = randv(7, 7);
        for be in [detect().unwrap_or(ActiveBackend::Scalar), ActiveBackend::Scalar] {
            let mut row = base.clone();
            dist2_epilogue(&mut row, 1.25, &nb, be);
            let mut expect = base.clone();
            for (v, &nbj) in expect.iter_mut().zip(nb.iter()) {
                *v = (1.25 + nbj - 2.0 * *v).max(0.0);
            }
            for (x, y) in row.iter().zip(expect.iter()) {
                assert_eq!(x.to_bits(), y.to_bits(), "epilogue is exact on every tier");
            }
        }
    }

    #[test]
    fn zero_k_and_empty_shapes() {
        let mut out = vec![1.0; 6];
        gemm_nt(&mut out, &[], &[], 2, 3, 0, ActiveBackend::Scalar);
        assert!(out.iter().all(|&v| v == 0.0));
        let mut gram = vec![1.0; 4];
        syrk_lower(&mut gram, &[], 2, 0, ActiveBackend::Scalar);
        assert!(gram.iter().all(|&v| v == 0.0));
        let mut norms = vec![1.0; 3];
        row_norms2(&[], 3, 0, &mut norms, ActiveBackend::Scalar);
        assert!(norms.iter().all(|&v| v == 0.0));
    }
}
