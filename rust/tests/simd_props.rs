//! Dispatch-level tests for the SIMD kernel tier: the env-knob roundtrip
//! and whole-pipeline invariants with the SIMD tier *installed*
//! process-wide (unlike `tests/kernel_props.rs`, whose SIMD coverage is
//! direct-call only).
//!
//! These tests mutate the process-wide dispatch decision and the
//! `CONTAINERSTRESS_KERNEL` env var, so they live in their own test
//! binary and serialize on a mutex — cargo's in-process test threads must
//! not observe each other's tier flips.

use containerstress::linalg::kernel::{dist2_cross_into, dist2_sym_into};
use containerstress::linalg::{simd, Mat, Workspace};
use containerstress::mset::{sim_cross, sim_matrix};
use containerstress::util::rng::Rng;
use std::sync::Mutex;

static LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    // a failed sibling test poisons the mutex; the guard itself is fine
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn random_mat(rng: &mut Rng, rows: usize, cols: usize) -> Mat {
    let mut m = Mat::zeros(rows, cols);
    rng.fill_gauss(&mut m.data);
    m
}

/// Leave the process in the documented default state on the way out.
fn restore() {
    std::env::remove_var(simd::ENV_KNOB);
    simd::install(simd::BackendRequest::Scalar, "test").expect("scalar install cannot fail");
}

#[test]
fn env_knob_roundtrip() {
    let _g = lock();

    // unset → scalar via "default"
    std::env::remove_var(simd::ENV_KNOB);
    simd::reset_for_tests();
    assert_eq!(simd::active(), simd::ActiveBackend::Scalar);
    let info = simd::dispatch_info();
    assert_eq!(info.requested, simd::BackendRequest::Scalar);
    assert_eq!(info.source, "default");

    // explicit scalar → scalar via "env"
    std::env::set_var(simd::ENV_KNOB, "scalar");
    simd::reset_for_tests();
    assert_eq!(simd::active(), simd::ActiveBackend::Scalar);
    assert_eq!(simd::dispatch_info().source, "env");

    // auto → the detected tier when present, else scalar; never an error
    std::env::set_var(simd::ENV_KNOB, "auto");
    simd::reset_for_tests();
    let auto_active = simd::active();
    assert_eq!(auto_active, simd::detect().unwrap_or(simd::ActiveBackend::Scalar));
    assert_eq!(simd::dispatch_info().source, "env");

    // simd → the detected tier, or a warned scalar fallback (the service
    // must come up even when the knob over-asks)
    std::env::set_var(simd::ENV_KNOB, "SIMD"); // case-insensitive
    simd::reset_for_tests();
    match simd::detect() {
        Some(tier) => {
            assert_eq!(simd::active(), tier);
            assert_eq!(simd::dispatch_info().source, "env");
        }
        None => {
            assert_eq!(simd::active(), simd::ActiveBackend::Scalar);
            assert_eq!(simd::dispatch_info().source, "env-fallback");
        }
    }

    // garbage → scalar with a warning, never a crash
    std::env::set_var(simd::ENV_KNOB, "warp");
    simd::reset_for_tests();
    assert_eq!(simd::active(), simd::ActiveBackend::Scalar);
    assert_eq!(simd::dispatch_info().source, "default");

    restore();
}

#[test]
fn explicit_simd_install_errors_without_hardware() {
    let _g = lock();
    match simd::detect() {
        Some(tier) => {
            let info = simd::install(simd::BackendRequest::Simd, "test").expect("tier detected");
            assert_eq!(info.active, tier);
            assert!(info.active.is_simd());
            assert_eq!(info.active.mode(), "tolerance");
        }
        None => {
            assert!(simd::install(simd::BackendRequest::Simd, "test").is_err());
        }
    }
    restore();
}

#[test]
fn installed_simd_pipeline_matches_scalar_and_keeps_exact_invariants() {
    let _g = lock();
    let Some(tier) = simd::detect() else {
        println!("simd_props: no SIMD tier on this host; skipping installed-pipeline test");
        restore();
        return;
    };

    let mut rng = Rng::new(42);
    let d = random_mat(&mut rng, 37, 11); // odd shapes: tile edges + tails
    let x = random_mat(&mut rng, 23, 11);

    simd::install(simd::BackendRequest::Scalar, "test").expect("scalar install cannot fail");
    let k_scalar = sim_cross(&d, &x);
    let s_scalar = sim_matrix(&d);

    simd::install(simd::BackendRequest::Simd, "test").expect("tier detected");
    assert_eq!(simd::active(), tier);
    let k_simd = sim_cross(&d, &x);
    let s_simd = sim_matrix(&d);

    // tolerance mode: ≤ 1e-10 against the scalar tier
    assert!(
        k_simd.max_abs_diff(&k_scalar) <= 1e-10,
        "sim_cross diverged: {}",
        k_simd.max_abs_diff(&k_scalar)
    );
    assert!(
        s_simd.max_abs_diff(&s_scalar) <= 1e-10,
        "sim_matrix diverged: {}",
        s_simd.max_abs_diff(&s_scalar)
    );

    // exact invariants that survive under the SIMD tier: self-similarity
    // equals the Gram path bit for bit, and the diagonal is exactly 1
    let k_self = sim_cross(&d, &d);
    for i in 0..d.rows {
        for j in 0..d.rows {
            assert_eq!(
                k_self[(i, j)].to_bits(),
                s_simd[(i, j)].to_bits(),
                "sim_cross(d,d) != sim_matrix(d) at ({i},{j}) under SIMD"
            );
        }
        assert_eq!(s_simd[(i, i)], 1.0, "diag ({i}) not exactly 1 under SIMD");
    }

    // dist2_sym == dist2_cross(a, a) bitwise, zero diagonal
    let mut ws = Workspace::new();
    let mut sym = Mat::zeros(0, 0);
    let mut cross = Mat::zeros(0, 0);
    dist2_sym_into(&mut sym, &d, &mut ws);
    dist2_cross_into(&mut cross, &d, &d, &mut ws);
    for i in 0..d.rows {
        for j in 0..d.rows {
            assert_eq!(
                sym[(i, j)].to_bits(),
                cross[(i, j)].to_bits(),
                "dist2_sym != dist2_cross(a,a) at ({i},{j}) under SIMD"
            );
        }
        assert_eq!(sym[(i, i)], 0.0, "dist2 diag ({i}) not exactly 0 under SIMD");
    }

    restore();
}
