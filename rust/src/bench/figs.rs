//! Shared support for the paper-figure bench binaries (`benches/fig*.rs`).
//!
//! Each bench regenerates one evaluation artefact of the paper on the
//! scaled grid (DESIGN.md §5/§6). The helpers here measure single cells
//! through the device path with fresh TPSS data per trial and write the
//! combined CSV/ASCII/gnuplot outputs under `results/`.

use crate::linalg::Mat;
use crate::mset;
use crate::runtime::mset::DeviceMset;
use crate::runtime::{DeviceHandle, DeviceServer};
use crate::tpss::{synthesize, TpssConfig};

/// Start the device server, or exit cleanly with instructions when the
/// artifacts are missing (bench binaries must not hard-fail a fresh tree).
pub fn device_or_exit() -> DeviceServer {
    let dir = crate::runtime::default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!(
            "bench: no artifacts at {} — run `make artifacts` (ARTIFACT_PROFILE=full for the full grids)",
            dir.display()
        );
        std::process::exit(0);
    }
    DeviceServer::start(&dir).expect("device server")
}

/// Signal/memvec bucket axes actually available in the manifest (the bench
/// grids adapt to the dev or full artifact profile automatically).
pub fn available_axes(handle: &DeviceHandle) -> (Vec<usize>, Vec<usize>) {
    let man = handle.manifest().expect("manifest");
    let mut signals: Vec<usize> = man.artifacts.iter().map(|a| a.n).collect();
    let mut memvecs: Vec<usize> = man.artifacts.iter().map(|a| a.m).collect();
    signals.sort_unstable();
    signals.dedup();
    memvecs.sort_unstable();
    memvecs.dedup();
    (signals, memvecs)
}

/// Prepare a device session with a freshly synthesized, selected memory
/// matrix for exact bucket shape (n, m).
pub fn session_for(handle: &DeviceHandle, n: usize, m: usize, seed: u64) -> DeviceMset {
    let ds = synthesize(&TpssConfig::sized(n, (2 * m).max(256)), seed);
    let scaler = mset::Scaler::fit(&ds.data);
    let xs = scaler.transform(&ds.data);
    let idx = mset::select_memory(&xs, m);
    let mut d = Mat::zeros(m, n);
    for (r, &i) in idx.iter().enumerate() {
        d.row_mut(r).copy_from_slice(xs.row(i));
    }
    DeviceMset::new(handle.clone(), &d).expect("session")
}

/// Measure device **training** cost for `trials` independent memory
/// matrices selected from an `n_train`-observation window. Matches the
/// coordinator's accounting: scaling + memory-vector selection (training
/// work proportional to `n_train`) plus the training executable.
pub fn measure_train(
    handle: &DeviceHandle,
    n: usize,
    m: usize,
    n_train: usize,
    trials: usize,
) -> Vec<f64> {
    (0..trials)
        .map(|t| {
            let ds = synthesize(
                &TpssConfig::sized(n, n_train.max(m)),
                0xF16_4 + t as u64,
            );
            let t0 = std::time::Instant::now();
            let scaler = mset::Scaler::fit(&ds.data);
            let xs = scaler.transform(&ds.data);
            let idx = mset::select_memory(&xs, m);
            let mut d = Mat::zeros(m, n);
            for (r, &i) in idx.iter().enumerate() {
                d.row_mut(r).copy_from_slice(xs.row(i));
            }
            let prep = t0.elapsed().as_secs_f64();
            let mut sess = DeviceMset::new(handle.clone(), &d).expect("session");
            let (_, cost) = sess.train().expect("train");
            prep + cost.exec.as_secs_f64()
        })
        .collect()
}

/// Measure device **surveillance** cost (pure exec seconds) of streaming
/// `n_obs` observations, `trials` times.
pub fn measure_surveil(
    handle: &DeviceHandle,
    n: usize,
    m: usize,
    n_obs: usize,
    trials: usize,
) -> Vec<f64> {
    let mut sess = session_for(handle, n, m, 0xF16_5);
    sess.train().expect("train");
    (0..trials)
        .map(|t| {
            let probe = synthesize(&TpssConfig::sized(n, n_obs), 0xF16_6 + t as u64);
            // scaling is data prep, not the measured streaming phase
            let scaler = mset::Scaler::fit(&probe.data);
            let xs = scaler.transform(&probe.data);
            let (_, _, cost) = sess.surveil(&xs).expect("surveil");
            cost.exec.as_secs_f64()
        })
        .collect()
}

/// `--quick` flag support for every bench binary (CI-friendly runtimes).
pub fn quick() -> bool {
    std::env::args().any(|a| a == "--quick") || std::env::var("CS_BENCH_QUICK").is_ok()
}

/// Median of a sample (bench cells report medians).
pub fn median(xs: &[f64]) -> f64 {
    crate::util::Summary::of(xs).median
}

#[cfg(test)]
mod tests {
    #[test]
    fn median_helper() {
        assert_eq!(super::median(&[3.0, 1.0, 2.0]), 2.0);
    }
}
