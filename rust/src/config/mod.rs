//! Configuration system for the launcher.
//!
//! JSON config files (own parser — no serde offline) with CLI-flag
//! overrides, profile presets, and validation. Every `containerstress`
//! subcommand builds its effective configuration through here, so runs are
//! reproducible from a single file.

use crate::coordinator::SweepSpec;
use crate::util::cli::Args;
use crate::util::json::Json;
use std::path::PathBuf;

/// Effective run configuration.
#[derive(Clone, Debug)]
pub struct Config {
    pub artifact_dir: PathBuf,
    pub output_dir: PathBuf,
    /// Execution backend: "device" | "native".
    pub backend: String,
    pub sweep: SweepSpec,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            artifact_dir: crate::runtime::default_artifact_dir(),
            output_dir: PathBuf::from("results"),
            backend: "device".into(),
            sweep: SweepSpec::default(),
        }
    }
}

fn usize_list(j: &Json) -> Option<Vec<usize>> {
    j.as_arr()
        .map(|a| a.iter().filter_map(|v| v.as_usize()).collect())
}

impl Config {
    /// Load from a JSON file (all keys optional; defaults fill the rest).
    pub fn from_file(path: &str) -> anyhow::Result<Config> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("config {path}: {e}"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("config {path}: {e}"))?;
        let mut cfg = Config::default();
        cfg.apply_json(&j);
        cfg.validate()?;
        Ok(cfg)
    }

    fn apply_json(&mut self, j: &Json) {
        if let Some(v) = j.get("artifact_dir").and_then(Json::as_str) {
            self.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("output_dir").and_then(Json::as_str) {
            self.output_dir = PathBuf::from(v);
        }
        if let Some(v) = j.get("backend").and_then(Json::as_str) {
            self.backend = v.to_string();
        }
        if let Some(s) = j.get("sweep") {
            if let Some(v) = s.get("signals").and_then(usize_list) {
                self.sweep.signals = v;
            }
            if let Some(v) = s.get("memvecs").and_then(usize_list) {
                self.sweep.memvecs = v;
            }
            if let Some(v) = s.get("obs").and_then(usize_list) {
                self.sweep.obs = v;
            }
            if let Some(v) = s.get("trials").and_then(Json::as_usize) {
                self.sweep.trials = v;
            }
            if let Some(v) = s.get("seed").and_then(|x| x.as_f64()) {
                self.sweep.seed = v as u64;
            }
            if let Some(v) = s.get("model").and_then(Json::as_str) {
                self.sweep.model = v.to_string();
            }
            if let Some(v) = s.get("workers").and_then(Json::as_usize) {
                self.sweep.workers = v;
            }
        }
    }

    /// Apply CLI overrides (highest precedence).
    pub fn apply_args(&mut self, args: &Args) -> anyhow::Result<()> {
        if let Some(v) = args.get("artifacts") {
            self.artifact_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("out") {
            self.output_dir = PathBuf::from(v);
        }
        if let Some(v) = args.get("backend") {
            self.backend = v.to_string();
        }
        if let Some(v) = args.get("model") {
            self.sweep.model = v.to_string();
        }
        self.sweep.signals = args.get_usize_list("signals", &self.sweep.signals)?;
        self.sweep.memvecs = args.get_usize_list("memvecs", &self.sweep.memvecs)?;
        self.sweep.obs = args.get_usize_list("obs", &self.sweep.obs)?;
        self.sweep.trials = args.get_usize("trials", self.sweep.trials)?;
        self.sweep.seed = args.get_u64("seed", self.sweep.seed)?;
        self.sweep.workers = args.get_usize("workers", self.sweep.workers)?;
        self.validate()
    }

    /// Build the effective config: optional `--config file` then flags.
    pub fn resolve(args: &Args) -> anyhow::Result<Config> {
        let mut cfg = match args.get("config") {
            Some(path) => Config::from_file(path)?,
            None => Config::default(),
        };
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            matches!(self.backend.as_str(), "device" | "native"),
            "backend must be 'device' or 'native', got '{}'",
            self.backend
        );
        anyhow::ensure!(
            matches!(
                self.sweep.model.as_str(),
                "mset2" | "aakr" | "ridge" | "mlp" | "svr"
            ),
            "model must be mset2|aakr|ridge|mlp|svr, got '{}'",
            self.sweep.model
        );
        anyhow::ensure!(self.sweep.trials >= 1, "trials must be ≥ 1");
        anyhow::ensure!(
            !self.sweep.signals.is_empty()
                && !self.sweep.memvecs.is_empty()
                && !self.sweep.obs.is_empty(),
            "sweep axes must be non-empty"
        );
        Ok(())
    }

    /// Serialise back to JSON (for run provenance in results/).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            (
                "artifact_dir",
                Json::Str(self.artifact_dir.display().to_string()),
            ),
            (
                "output_dir",
                Json::Str(self.output_dir.display().to_string()),
            ),
            ("backend", Json::Str(self.backend.clone())),
            (
                "sweep",
                Json::obj(vec![
                    (
                        "signals",
                        Json::arr_f64(
                            &self.sweep.signals.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "memvecs",
                        Json::arr_f64(
                            &self.sweep.memvecs.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                        ),
                    ),
                    (
                        "obs",
                        Json::arr_f64(
                            &self.sweep.obs.iter().map(|&v| v as f64).collect::<Vec<_>>(),
                        ),
                    ),
                    ("trials", Json::Num(self.sweep.trials as f64)),
                    ("seed", Json::Num(self.sweep.seed as f64)),
                    ("model", Json::Str(self.sweep.model.clone())),
                    ("workers", Json::Num(self.sweep.workers as f64)),
                ]),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let mut cfg = Config::default();
        cfg.apply_args(&args(
            "sweep --signals 4,8 --trials 5 --model aakr --backend native",
        ))
        .unwrap();
        assert_eq!(cfg.sweep.signals, vec![4, 8]);
        assert_eq!(cfg.sweep.trials, 5);
        assert_eq!(cfg.sweep.model, "aakr");
        assert_eq!(cfg.backend, "native");
    }

    #[test]
    fn bad_values_rejected() {
        let mut cfg = Config::default();
        assert!(cfg.apply_args(&args("x --backend warp")).is_err());
        let mut cfg = Config::default();
        assert!(cfg.apply_args(&args("x --model svm")).is_err());
        let mut cfg = Config::default();
        assert!(cfg.apply_args(&args("x --trials 0")).is_err());
    }

    #[test]
    fn file_roundtrip() {
        let cfg0 = {
            let mut c = Config::default();
            c.sweep.signals = vec![8, 16, 32];
            c.sweep.model = "ridge".into();
            c.backend = "native".into();
            c
        };
        let path = std::env::temp_dir().join("cs_config_test.json");
        std::fs::write(&path, cfg0.to_json().to_pretty()).unwrap();
        let cfg1 = Config::from_file(path.to_str().unwrap()).unwrap();
        assert_eq!(cfg1.sweep.signals, vec![8, 16, 32]);
        assert_eq!(cfg1.sweep.model, "ridge");
        assert_eq!(cfg1.backend, "native");
    }

    #[test]
    fn resolve_config_plus_flags() {
        let path = std::env::temp_dir().join("cs_config_test2.json");
        std::fs::write(
            &path,
            r#"{"backend": "native", "sweep": {"trials": 7}}"#,
        )
        .unwrap();
        let a = args(&format!(
            "sweep --config {} --trials 9",
            path.to_str().unwrap()
        ));
        let cfg = Config::resolve(&a).unwrap();
        assert_eq!(cfg.backend, "native"); // from file
        assert_eq!(cfg.sweep.trials, 9); // flag wins
    }
}
