//! Cache-blocked, register-tiled kernel core for the native numeric hot
//! path (the paper's §II.D hot-spot, re-thought for CPU the way the L1
//! Pallas kernel re-thinks it for the MXU).
//!
//! Everything here is built on one micro-kernel: a 4×4 register tile of
//! `C = A·Bᵀ` over row-major operands, unrolled into 16 independent
//! accumulators (the "4-accumulator unroll" along each of the two tile
//! axes). The other entry points reduce to it:
//!
//! - [`matmul_into`] (`A·B`) packs a transposed copy of `B` (the packed
//!   B panel) so the micro-kernel streams both operands contiguously;
//! - [`matmul_nt_into`] (`A·Bᵀ`) and [`syrk_into`] (`A·Aᵀ`) need no
//!   packing at all — row-major rows *are* the panels;
//! - [`dist2_cross_into`] / [`dist2_sym_into`] fuse the squared-distance
//!   expansion ‖a−b‖² = ‖a‖² + ‖b‖² − 2a·b over the same core — the
//!   exact formulation the L1 kernel uses on the MXU.
//!
//! ## Bit-stability contract
//!
//! The `k` (reduction) dimension is never split: every output element is
//! one register accumulator fed in ascending `k` order, so each element's
//! floating-point op sequence is **identical to the naive sequential dot
//! product** (`rustc` does not contract `a*b + c` into FMA, and no
//! reduction is reassociated). Consequences the rest of the crate relies
//! on:
//!
//! - blocked results match the [`reference`] implementations bit for bit
//!   (the 1e-12 property bounds in `tests/kernel_props.rs` are slack);
//! - [`dist2_sym_into`] reads row norms off the Gram diagonal, and
//!   [`dist2_cross_into`]'s separate norm pass performs the same op
//!   sequence — so `sim_cross(d, d)` equals `sim_matrix(d)` *exactly*,
//!   diagonal included (`x + x − 2x ≡ 0` in IEEE arithmetic);
//! - zero-padding the `k` dimension appends exact `+0.0` terms to the
//!   tail of each accumulation, leaving every result bit-identical —
//!   the invariant the bucket router's padded executions rely on.
//!
//! Cache behaviour: tiles walk `i` then `j` with full-`k` panels. Panels
//! are contiguous rows (packed for the `A·B` case), so the reduction
//! streams sequentially and hardware prefetch covers the paper grid's
//! shapes (`n ≤ 1024` ⇒ a 4-row panel is ≤ 32 KiB). `benches/
//! kernel_hotpath.rs` gates the resulting speedups and emits
//! `BENCH_kernel.json`.
//!
//! ## The SIMD tier
//!
//! The hot entry points ([`gemm_nt`], [`syrk_into`], [`row_norms2`], and
//! the `dist2_*` epilogues) consult [`simd::active`](super::simd::active)
//! once per call (a cached atomic load) and route to the explicit-SIMD
//! tier in [`super::simd`] when one was opted into via `--kernel-backend`
//! / `CONTAINERSTRESS_KERNEL`. That tier runs in **tolerance mode** —
//! ≤ 1e-10 agreement with the references instead of bit-identity — while
//! preserving the cross-kernel exact invariants above; see the `simd`
//! module docs for the precise contract. The scalar blocked code below is
//! the default and keeps the bit-stability contract intact.

use super::mat::Mat;
use super::simd;
use super::workspace::Workspace;

/// Register-tile rows (A-side unroll).
const MR: usize = 4;
/// Register-tile columns (B-side unroll — the 4 accumulators per A row).
const NR: usize = 4;

/// One `ib×jb` tile (`ib, jb ≤ 4`) of `C = A·Bᵀ` into `out` (row stride
/// `ld`). Full tiles run the 16-accumulator micro-kernel; edge tiles fall
/// back to scalar dots with the same ascending-`k` accumulation order.
#[allow(clippy::too_many_arguments)]
#[inline]
fn tile_nt(
    out: &mut [f64],
    ld: usize,
    a: &[f64],
    b: &[f64],
    k: usize,
    i0: usize,
    ib: usize,
    j0: usize,
    jb: usize,
) {
    if ib == MR && jb == NR {
        let a0 = &a[i0 * k..][..k];
        let a1 = &a[(i0 + 1) * k..][..k];
        let a2 = &a[(i0 + 2) * k..][..k];
        let a3 = &a[(i0 + 3) * k..][..k];
        let b0 = &b[j0 * k..][..k];
        let b1 = &b[(j0 + 1) * k..][..k];
        let b2 = &b[(j0 + 2) * k..][..k];
        let b3 = &b[(j0 + 3) * k..][..k];
        let mut c = [[0.0f64; NR]; MR];
        for t in 0..k {
            let av = [a0[t], a1[t], a2[t], a3[t]];
            let bv = [b0[t], b1[t], b2[t], b3[t]];
            for (cr, &ar) in c.iter_mut().zip(av.iter()) {
                for (cc, &bc) in cr.iter_mut().zip(bv.iter()) {
                    *cc += ar * bc;
                }
            }
        }
        for (r, cr) in c.iter().enumerate() {
            out[(i0 + r) * ld + j0..][..NR].copy_from_slice(cr);
        }
    } else {
        for r in 0..ib {
            let ar = &a[(i0 + r) * k..][..k];
            for s in 0..jb {
                let br = &b[(j0 + s) * k..][..k];
                let mut acc = 0.0;
                for (x, y) in ar.iter().zip(br.iter()) {
                    acc += x * y;
                }
                out[(i0 + r) * ld + j0 + s] = acc;
            }
        }
    }
}

/// `out[m×n] = A[m×k] · B[n×k]ᵀ`, all row-major, `out` overwritten.
/// The workhorse: both operands stream their rows contiguously, so no
/// packing is needed.
pub fn gemm_nt(out: &mut [f64], a: &[f64], b: &[f64], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * k, "gemm_nt: A buffer size");
    assert_eq!(b.len(), n * k, "gemm_nt: B buffer size");
    assert_eq!(out.len(), m * n, "gemm_nt: C buffer size");
    if k == 0 {
        out.fill(0.0);
        return;
    }
    let backend = simd::active();
    if backend.is_simd() {
        simd::gemm_nt(out, a, b, m, n, k, backend);
        return;
    }
    let mut i0 = 0;
    while i0 < m {
        let ib = (m - i0).min(MR);
        let mut j0 = 0;
        while j0 < n {
            let jb = (n - j0).min(NR);
            tile_nt(out, n, a, b, k, i0, ib, j0, jb);
            j0 += jb;
        }
        i0 += ib;
    }
}

/// Blocked transpose: `dst[c·rows + r] = src[r·cols + c]`. Used to build
/// the packed B panels for [`matmul_into`] and by `Mat::transpose`.
pub fn pack_transpose(dst: &mut [f64], src: &[f64], rows: usize, cols: usize) {
    assert_eq!(src.len(), rows * cols, "pack_transpose: src size");
    assert_eq!(dst.len(), rows * cols, "pack_transpose: dst size");
    const BLK: usize = 32;
    for r0 in (0..rows).step_by(BLK) {
        let r1 = (r0 + BLK).min(rows);
        for c0 in (0..cols).step_by(BLK) {
            let c1 = (c0 + BLK).min(cols);
            for r in r0..r1 {
                for c in c0..c1 {
                    dst[c * rows + r] = src[r * cols + c];
                }
            }
        }
    }
}

/// `out = A·B` (the general product): packs `Bᵀ` into workspace scratch,
/// then runs the [`gemm_nt`] core. Element-for-element bit-identical to
/// the naive i-k-j reference (see the module docs).
pub fn matmul_into(out: &mut Mat, a: &Mat, b: &Mat, ws: &mut Workspace) {
    assert_eq!(a.cols, b.rows, "matmul dims");
    let mut bt = ws.take_f64(b.rows * b.cols);
    pack_transpose(&mut bt, &b.data, b.rows, b.cols);
    out.reshape(a.rows, b.cols);
    gemm_nt(&mut out.data, &a.data, &bt, a.rows, b.cols, a.cols);
    ws.give_f64(bt);
}

/// `out = A·Bᵀ` with both operands row-major — no packing needed.
pub fn matmul_nt_into(out: &mut Mat, a: &Mat, b: &Mat, ws: &mut Workspace) {
    let _ = ws; // same signature as the other entry points
    assert_eq!(a.cols, b.cols, "matmul_nt dims");
    out.reshape(a.rows, b.rows);
    gemm_nt(&mut out.data, &a.data, &b.data, a.rows, b.rows, a.cols);
}

/// `out = Aᵀ·B` (`A: k×m`, `B: k×n`): packs both transposes, then runs
/// the core. Used by the MLP gradient products.
pub fn matmul_tn_into(out: &mut Mat, a: &Mat, b: &Mat, ws: &mut Workspace) {
    assert_eq!(a.rows, b.rows, "matmul_tn dims");
    let (k, m, n) = (a.rows, a.cols, b.cols);
    let mut at = ws.take_f64(k * m);
    let mut bt = ws.take_f64(k * n);
    pack_transpose(&mut at, &a.data, k, m);
    pack_transpose(&mut bt, &b.data, k, n);
    out.reshape(m, n);
    gemm_nt(&mut out.data, &at, &bt, m, n, k);
    ws.give_f64(bt);
    ws.give_f64(at);
}

/// Symmetric rank-k product `out = A·Aᵀ` (`A: m×k`): only the lower
/// triangle is computed (half the tile work of [`gemm_nt`]), then
/// mirrored — so the result is *exactly* symmetric.
pub fn syrk_into(out: &mut Mat, a: &Mat) {
    let m = a.rows;
    let k = a.cols;
    out.reshape(m, m);
    if k == 0 {
        out.data.fill(0.0);
        return;
    }
    let data = &mut out.data;
    let src = &a.data;
    let backend = simd::active();
    if backend.is_simd() {
        simd::syrk_lower(data, src, m, k, backend);
    } else {
        let mut i0 = 0;
        while i0 < m {
            let ib = (m - i0).min(MR);
            let mut j0 = 0;
            while j0 < i0 + ib {
                let jb = (m - j0).min(NR);
                if ib == MR && jb == NR && j0 + NR <= i0 {
                    // tile strictly below the diagonal: full micro-kernel
                    tile_nt(data, m, src, src, k, i0, ib, j0, jb);
                } else {
                    // diagonal-crossing or edge tile: scalar dots, lower only
                    for r in i0..i0 + ib {
                        let ar = &src[r * k..][..k];
                        let hi = (j0 + jb).min(r + 1);
                        for s in j0..hi {
                            let br = &src[s * k..][..k];
                            let mut acc = 0.0;
                            for (x, y) in ar.iter().zip(br.iter()) {
                                acc += x * y;
                            }
                            data[r * m + s] = acc;
                        }
                    }
                }
                j0 += jb;
            }
            i0 += ib;
        }
    }
    // mirror the lower triangle up
    for i in 0..m {
        for j in i + 1..m {
            data[i * m + j] = data[j * m + i];
        }
    }
}

/// Per-row squared norms `out[i] = ‖A[i]‖²`, accumulated in ascending
/// column order — the same op sequence as the [`syrk_into`] diagonal, so
/// the two are bit-interchangeable (see the module docs).
pub fn row_norms2(a: &Mat, out: &mut [f64]) {
    assert_eq!(out.len(), a.rows, "row_norms2: output size");
    if a.cols == 0 {
        out.fill(0.0);
        return;
    }
    let backend = simd::active();
    if backend.is_simd() {
        simd::row_norms2(&a.data, a.rows, a.cols, out, backend);
        return;
    }
    for (o, row) in out.iter_mut().zip(a.data.chunks_exact(a.cols)) {
        let mut acc = 0.0;
        for &v in row {
            acc += v * v;
        }
        *o = acc;
    }
}

/// Pairwise squared distances `out[i][j] = max(‖a_i‖² + ‖b_j‖² −
/// 2·a_i·b_j, 0)` between the rows of `a` (`m×k`) and `b` (`n×k`),
/// computed over the blocked Gram core. The clamp absorbs the expansion's
/// cancellation so downstream `sqrt` never sees a negative.
pub fn dist2_cross_into(out: &mut Mat, a: &Mat, b: &Mat, ws: &mut Workspace) {
    assert_eq!(a.cols, b.cols, "dist2_cross: column mismatch");
    let (m, n) = (a.rows, b.rows);
    out.reshape(m, n);
    if m == 0 || n == 0 {
        return;
    }
    gemm_nt(&mut out.data, &a.data, &b.data, m, n, a.cols);
    let mut na = ws.take_f64(m);
    let mut nb = ws.take_f64(n);
    row_norms2(a, &mut na);
    row_norms2(b, &mut nb);
    let backend = simd::active();
    for (row, &nai) in out.data.chunks_exact_mut(n).zip(na.iter()) {
        if backend.is_simd() {
            simd::dist2_epilogue(row, nai, &nb, backend);
        } else {
            for (v, &nbj) in row.iter_mut().zip(nb.iter()) {
                *v = (nai + nbj - 2.0 * *v).max(0.0);
            }
        }
    }
    ws.give_f64(nb);
    ws.give_f64(na);
}

/// Symmetric pairwise squared distances between the rows of `a`: the
/// Gram matrix comes from [`syrk_into`] (half the work, exact symmetry),
/// row norms are read off its diagonal, and the diagonal distance is
/// exactly `0.0`. Bit-identical to [`dist2_cross_into`]`(a, a)`.
pub fn dist2_sym_into(out: &mut Mat, a: &Mat, ws: &mut Workspace) {
    let m = a.rows;
    syrk_into(out, a);
    if m == 0 {
        return;
    }
    let mut nrm = ws.take_f64(m);
    for (i, v) in nrm.iter_mut().enumerate() {
        *v = out.data[i * m + i];
    }
    let backend = simd::active();
    for (i, row) in out.data.chunks_exact_mut(m).enumerate() {
        if backend.is_simd() {
            // the epilogue already yields +0.0 on the diagonal
            // (x + x − 2x ≡ 0, clamped); the store keeps the scalar
            // tier's explicit-zero contract byte for byte
            simd::dist2_epilogue(row, nrm[i], &nrm, backend);
            row[i] = 0.0;
        } else {
            for (j, v) in row.iter_mut().enumerate() {
                *v = if i == j {
                    0.0
                } else {
                    (nrm[i] + nrm[j] - 2.0 * *v).max(0.0)
                };
            }
        }
    }
    ws.give_f64(nrm);
}

/// Naive single-accumulator references the blocked kernels are validated
/// against — by `tests/kernel_props.rs` (≤ 1e-12 across random shapes)
/// and by `benches/kernel_hotpath.rs` (≤ 1e-10 plus the asserted
/// speedups). Kept `pub` so benches and tests share one oracle.
pub mod reference {
    use super::Mat;

    /// Naive i-k-j `A·B` (per-element ascending-`k` accumulation).
    pub fn matmul(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.rows, "matmul dims");
        let mut out = Mat::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for k in 0..a.cols {
                let av = a[(i, k)];
                for j in 0..b.cols {
                    out[(i, j)] += av * b[(k, j)];
                }
            }
        }
        out
    }

    /// Naive `A·Bᵀ`.
    pub fn matmul_nt(a: &Mat, b: &Mat) -> Mat {
        assert_eq!(a.cols, b.cols, "matmul_nt dims");
        let mut out = Mat::zeros(a.rows, b.rows);
        for i in 0..a.rows {
            for j in 0..b.rows {
                let mut acc = 0.0;
                for (x, y) in a.row(i).iter().zip(b.row(j).iter()) {
                    acc += x * y;
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    /// Naive `A·Aᵀ`.
    pub fn syrk(a: &Mat) -> Mat {
        matmul_nt(a, a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(rows, cols);
        rng.fill_gauss(&mut m.data);
        m
    }

    #[test]
    fn gemm_nt_matches_reference_bitwise() {
        let a = random_mat(13, 17, 1);
        let b = random_mat(9, 17, 2);
        let mut out = Mat::zeros(13, 9);
        gemm_nt(&mut out.data, &a.data, &b.data, 13, 9, 17);
        let r = reference::matmul_nt(&a, &b);
        assert_eq!(out, r, "blocked gemm must be bit-identical to naive");
    }

    #[test]
    fn matmul_into_matches_reference_bitwise() {
        let mut ws = Workspace::new();
        let a = random_mat(11, 7, 3);
        let b = random_mat(7, 15, 4);
        let mut out = Mat::zeros(0, 0);
        matmul_into(&mut out, &a, &b, &mut ws);
        assert_eq!(out, reference::matmul(&a, &b));
    }

    #[test]
    fn matmul_tn_matches_transposed_reference() {
        let mut ws = Workspace::new();
        let a = random_mat(12, 5, 5);
        let b = random_mat(12, 6, 6);
        let mut out = Mat::zeros(0, 0);
        matmul_tn_into(&mut out, &a, &b, &mut ws);
        let r = reference::matmul(&a.transpose(), &b);
        assert!(out.max_abs_diff(&r) < 1e-12);
    }

    #[test]
    fn syrk_symmetric_and_matches_reference() {
        let a = random_mat(10, 6, 7);
        let mut out = Mat::zeros(0, 0);
        syrk_into(&mut out, &a);
        let r = reference::syrk(&a);
        assert_eq!(out, r, "syrk must be bit-identical to naive A·Aᵀ");
        for i in 0..10 {
            for j in 0..10 {
                assert_eq!(out[(i, j)].to_bits(), out[(j, i)].to_bits());
            }
        }
    }

    #[test]
    fn dist2_sym_equals_dist2_cross_bitwise() {
        let mut ws = Workspace::new();
        let a = random_mat(9, 5, 8);
        let mut sym = Mat::zeros(0, 0);
        let mut cross = Mat::zeros(0, 0);
        dist2_sym_into(&mut sym, &a, &mut ws);
        dist2_cross_into(&mut cross, &a, &a, &mut ws);
        assert_eq!(sym, cross);
        for i in 0..9 {
            assert_eq!(sym[(i, i)], 0.0);
        }
    }

    #[test]
    fn dist2_matches_direct_distance() {
        let mut ws = Workspace::new();
        let a = random_mat(8, 6, 9);
        let b = random_mat(5, 6, 10);
        let mut d2 = Mat::zeros(0, 0);
        dist2_cross_into(&mut d2, &a, &b, &mut ws);
        for i in 0..8 {
            for j in 0..5 {
                let direct: f64 = a
                    .row(i)
                    .iter()
                    .zip(b.row(j))
                    .map(|(x, y)| (x - y) * (x - y))
                    .sum();
                assert!((d2[(i, j)] - direct).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn empty_shapes_are_handled() {
        let mut ws = Workspace::new();
        let a = Mat::zeros(3, 0);
        let b = Mat::zeros(0, 4);
        let mut out = Mat::zeros(0, 0);
        matmul_into(&mut out, &a, &b, &mut ws);
        assert_eq!((out.rows, out.cols), (3, 4));
        assert!(out.data.iter().all(|&v| v == 0.0));
        let mut d2 = Mat::zeros(0, 0);
        dist2_cross_into(&mut d2, &Mat::zeros(0, 3), &Mat::zeros(2, 3), &mut ws);
        assert_eq!((d2.rows, d2.cols), (0, 2));
    }

    #[test]
    fn padding_k_is_exact() {
        // appending zero columns appends exact +0.0 terms — results are
        // bit-identical (the bucket-router invariant).
        let mut ws = Workspace::new();
        let a = random_mat(6, 5, 11);
        let b = random_mat(7, 5, 12);
        let mut ap = Mat::zeros(6, 9);
        let mut bp = Mat::zeros(7, 9);
        for r in 0..6 {
            ap.row_mut(r)[..5].copy_from_slice(a.row(r));
        }
        for r in 0..7 {
            bp.row_mut(r)[..5].copy_from_slice(b.row(r));
        }
        let mut d2 = Mat::zeros(0, 0);
        let mut d2p = Mat::zeros(0, 0);
        dist2_cross_into(&mut d2, &a, &b, &mut ws);
        dist2_cross_into(&mut d2p, &ap, &bp, &mut ws);
        assert_eq!(d2, d2p);
    }
}
