//! **TPSS** — Telemetry Parameter Synthesis System substrate.
//!
//! The paper's case study runs on signals synthesized by OracleLabs' TPSS
//! (refs [7–9]): signals that *match real IoT sensor telemetry in all
//! statistical characteristics important to ML prognostics* — serial
//! correlation, cross-correlation between signals, and stochastic content
//! (variance, skewness, kurtosis). TPSS itself is proprietary, so this
//! module implements the closest published construction (spectral
//! decomposition & reconstruction, ref [9]):
//!
//! 1. a **deterministic component** per signal — a sum of low-frequency
//!    spectral modes drawn from an industry archetype (rotating machinery,
//!    thermal, electrical), giving realistic serial correlation;
//! 2. a **stochastic component** — AR(1) coloured noise, cross-correlated
//!    across signals through a Cholesky factor of the target correlation
//!    matrix, then moment-shaped by a Fleishman cubic
//!    ([`shaping::fleishman`]) to hit target variance/skewness/kurtosis;
//! 3. optional **fault injection** (drift / step / spike / stuck) for
//!    detection studies.
//!
//! Statistical validity is enforced by the tests in this module and used by
//! the coordinator's Monte Carlo loops to generate every trial workload.

pub mod shaping;
pub mod stats;

use crate::linalg::{cholesky, Mat};
use crate::util::rng::Rng;
use shaping::Fleishman;

/// Industry archetype controlling the deterministic spectral signature.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Archetype {
    /// Slow sinusoidal drift + harmonics (pumps, turbines).
    Rotating,
    /// Very low frequency drift with long thermal time constants.
    Thermal,
    /// Line-frequency dominated with sharp harmonics.
    Electrical,
    /// Mixture of the above (a realistic heterogeneous asset).
    Mixed,
}

/// Specification of a synthesized telemetry dataset.
#[derive(Clone, Debug)]
pub struct TpssConfig {
    /// Number of correlated signals to synthesize.
    pub n_signals: usize,
    /// Number of observations (rows).
    pub n_obs: usize,
    /// Sampling interval in seconds (defines mode frequencies).
    pub dt: f64,
    /// Telemetry archetype shaping the spectral content.
    pub archetype: Archetype,
    /// Mean target cross-correlation of the stochastic component (0..0.95).
    pub cross_corr: f64,
    /// AR(1) coefficient of the stochastic component (serial correlation).
    pub ar_coeff: f64,
    /// Fraction of each signal's variance carried by the stochastic part.
    pub noise_frac: f64,
    /// Target skewness of the stochastic component.
    pub skewness: f64,
    /// Target kurtosis (normal = 3).
    pub kurtosis: f64,
    /// Per-signal standard deviation of the full signal.
    pub sigma: f64,
    /// Per-signal mean level.
    pub level: f64,
}

impl Default for TpssConfig {
    fn default() -> Self {
        TpssConfig {
            n_signals: 8,
            n_obs: 1024,
            dt: 1.0,
            archetype: Archetype::Mixed,
            cross_corr: 0.4,
            ar_coeff: 0.7,
            noise_frac: 0.3,
            skewness: 0.0,
            kurtosis: 3.0,
            sigma: 1.0,
            level: 10.0,
        }
    }
}

impl TpssConfig {
    /// Convenience: a config sized for a sweep cell.
    pub fn sized(n_signals: usize, n_obs: usize) -> TpssConfig {
        TpssConfig {
            n_signals,
            n_obs,
            ..TpssConfig::default()
        }
    }
}

/// A synthesized dataset: `data` is `n_obs × n_signals` (row = one
/// observation vector, matching MSET's convention).
#[derive(Clone, Debug)]
pub struct Dataset {
    /// Synthesized telemetry, observations × signals.
    pub data: Mat,
    /// The configuration that produced it.
    pub cfg: TpssConfig,
}

/// One deterministic spectral mode.
#[derive(Clone, Copy, Debug)]
struct Mode {
    freq: f64,
    amp: f64,
    phase: f64,
}

fn archetype_modes(arch: Archetype, rng: &mut Rng, dt: f64) -> Vec<Mode> {
    // Frequencies are relative to the Nyquist band implied by dt.
    let nyq = 0.5 / dt;
    let mut modes = Vec::new();
    let push = |modes: &mut Vec<Mode>, rng: &mut Rng, f_lo: f64, f_hi: f64, amp: f64| {
        modes.push(Mode {
            freq: rng.range_f64(f_lo * nyq, f_hi * nyq),
            amp: amp * rng.range_f64(0.6, 1.4),
            phase: rng.range_f64(0.0, std::f64::consts::TAU),
        });
    };
    match arch {
        Archetype::Rotating => {
            push(&mut modes, rng, 0.02, 0.08, 1.0);
            push(&mut modes, rng, 0.04, 0.16, 0.5); // harmonic band
            push(&mut modes, rng, 0.10, 0.30, 0.25);
        }
        Archetype::Thermal => {
            push(&mut modes, rng, 0.001, 0.01, 1.2);
            push(&mut modes, rng, 0.005, 0.02, 0.4);
        }
        Archetype::Electrical => {
            push(&mut modes, rng, 0.2, 0.4, 0.8);
            push(&mut modes, rng, 0.4, 0.8, 0.4);
            push(&mut modes, rng, 0.05, 0.1, 0.3);
        }
        Archetype::Mixed => {
            push(&mut modes, rng, 0.002, 0.02, 1.0);
            push(&mut modes, rng, 0.02, 0.1, 0.6);
            push(&mut modes, rng, 0.2, 0.5, 0.3);
        }
    }
    modes
}

/// Synthesize a dataset per `cfg`, deterministically from `seed`.
pub fn synthesize(cfg: &TpssConfig, seed: u64) -> Dataset {
    assert!(cfg.n_signals > 0 && cfg.n_obs > 1);
    assert!((0.0..0.96).contains(&cfg.cross_corr.abs()));
    assert!(cfg.ar_coeff.abs() < 1.0);
    assert!((0.0..=1.0).contains(&cfg.noise_frac));
    let mut rng = Rng::new(seed);
    let n = cfg.n_signals;
    let t = cfg.n_obs;

    // --- deterministic component per signal -------------------------------
    let mut det = Mat::zeros(t, n);
    for j in 0..n {
        let modes = archetype_modes(cfg.archetype, &mut rng, cfg.dt);
        let amp_norm: f64 = modes.iter().map(|m| 0.5 * m.amp * m.amp).sum::<f64>().sqrt();
        for i in 0..t {
            let time = i as f64 * cfg.dt;
            let mut v = 0.0;
            for m in &modes {
                v += m.amp * (std::f64::consts::TAU * m.freq * time + m.phase).sin();
            }
            det[(i, j)] = v / amp_norm.max(1e-12); // unit-variance-ish
        }
    }

    // --- stochastic component ---------------------------------------------
    // Target correlation matrix: compound symmetry (1 on diag, ρ off-diag).
    let mut corr = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            corr[(i, j)] = if i == j { 1.0 } else { cfg.cross_corr };
        }
    }
    let chol = cholesky(&corr).expect("compound-symmetry corr must be SPD for rho<1");
    let shaper = shaping::fleishman(cfg.skewness, cfg.kurtosis)
        .unwrap_or_else(Fleishman::identity);

    // AR(1) innovations scaled for unit marginal variance.
    let phi = cfg.ar_coeff;
    let innov_sd = (1.0 - phi * phi).sqrt();
    let mut state = vec![0.0f64; n];
    // innovation scratch allocated once and reused by every AR step —
    // the loop below runs t + 64 times per synthesized trial
    let mut z = vec![0.0f64; n];
    // burn-in so the chain forgets the zero start
    for _ in 0..64 {
        step_ar(&mut state, phi, innov_sd, &chol, &mut rng, &mut z);
    }
    let mut sto = Mat::zeros(t, n);
    for i in 0..t {
        step_ar(&mut state, phi, innov_sd, &chol, &mut rng, &mut z);
        for j in 0..n {
            sto[(i, j)] = shaper.apply(state[j]);
        }
    }

    // --- combine ------------------------------------------------------------
    let det_w = (1.0 - cfg.noise_frac).sqrt() * cfg.sigma;
    let sto_w = cfg.noise_frac.sqrt() * cfg.sigma;
    let mut data = Mat::zeros(t, n);
    for i in 0..t {
        for j in 0..n {
            data[(i, j)] = cfg.level + det_w * det[(i, j)] + sto_w * sto[(i, j)];
        }
    }
    Dataset {
        data,
        cfg: cfg.clone(),
    }
}

/// One AR(1) step. `z` is caller-owned innovation scratch (same length as
/// `state`), refilled here in draw order — reusing it keeps the
/// synthesis loop allocation-free without changing a single RNG draw.
fn step_ar(state: &mut [f64], phi: f64, innov_sd: f64, chol: &Mat, rng: &mut Rng, z: &mut [f64]) {
    debug_assert_eq!(state.len(), z.len());
    // correlated innovations: e = L z
    for zi in z.iter_mut() {
        *zi = rng.gauss();
    }
    for (j, s) in state.iter_mut().enumerate() {
        // lower-triangular row of the Cholesky factor, contiguous
        let lrow = &chol.data[j * chol.cols..j * chol.cols + j + 1];
        let mut e = 0.0;
        for (&l, &zk) in lrow.iter().zip(z.iter()) {
            e += l * zk;
        }
        *s = phi * *s + innov_sd * e;
    }
}

// --------------------------- fault injection --------------------------------

/// Degradation modes for detection studies.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Fault {
    /// Linear drift reaching `magnitude`·σ at the end of the window.
    Drift { magnitude: f64 },
    /// Instant offset of `magnitude`·σ from `at_frac` onward.
    Step { magnitude: f64 },
    /// Isolated spikes of `magnitude`·σ with the given per-sample probability.
    Spikes { magnitude: f64, prob: f64 },
    /// Sensor freezes at its current value from `at_frac` onward.
    Stuck,
}

/// Inject `fault` into `signal` of `ds` starting at fraction `at_frac` of the
/// window. Returns the first affected row index (ground truth for detection
/// latency measurements).
pub fn inject(ds: &mut Dataset, signal: usize, fault: Fault, at_frac: f64, seed: u64) -> usize {
    assert!(signal < ds.cfg.n_signals);
    assert!((0.0..1.0).contains(&at_frac));
    let t = ds.cfg.n_obs;
    let start = (at_frac * t as f64) as usize;
    let sigma = ds.cfg.sigma;
    let mut rng = Rng::new(seed ^ 0xFA17);
    match fault {
        Fault::Drift { magnitude } => {
            let span = (t - start).max(1) as f64;
            for i in start..t {
                let ramp = (i - start) as f64 / span;
                ds.data[(i, signal)] += magnitude * sigma * ramp;
            }
        }
        Fault::Step { magnitude } => {
            for i in start..t {
                ds.data[(i, signal)] += magnitude * sigma;
            }
        }
        Fault::Spikes { magnitude, prob } => {
            for i in start..t {
                if rng.f64() < prob {
                    let sign = if rng.f64() < 0.5 { -1.0 } else { 1.0 };
                    ds.data[(i, signal)] += sign * magnitude * sigma;
                }
            }
        }
        Fault::Stuck => {
            let frozen = ds.data[(start.saturating_sub(1), signal)];
            for i in start..t {
                ds.data[(i, signal)] = frozen;
            }
        }
    }
    start
}

#[cfg(test)]
mod tests {
    use super::stats::{autocorr, moments, pearson};
    use super::*;

    fn big_cfg() -> TpssConfig {
        TpssConfig {
            n_signals: 6,
            n_obs: 20_000,
            noise_frac: 1.0, // pure stochastic so moment targets are testable
            ar_coeff: 0.6,
            cross_corr: 0.5,
            ..TpssConfig::default()
        }
    }

    #[test]
    fn deterministic_for_seed() {
        let cfg = TpssConfig::sized(4, 256);
        let a = synthesize(&cfg, 99);
        let b = synthesize(&cfg, 99);
        assert_eq!(a.data, b.data);
        let c = synthesize(&cfg, 100);
        assert!(a.data.max_abs_diff(&c.data) > 1e-6);
    }

    #[test]
    fn marginal_moments_match_spec() {
        let cfg = big_cfg();
        let ds = synthesize(&cfg, 7);
        for j in 0..cfg.n_signals {
            let col: Vec<f64> = ds.data.col(j).collect();
            let m = moments(&col);
            assert!((m.mean - cfg.level).abs() < 0.15, "mean={}", m.mean);
            assert!(
                (m.var.sqrt() - cfg.sigma).abs() < 0.1 * cfg.sigma,
                "sd={}",
                m.var.sqrt()
            );
        }
    }

    #[test]
    fn serial_correlation_matches_ar_coeff() {
        let cfg = big_cfg();
        let ds = synthesize(&cfg, 11);
        for j in 0..cfg.n_signals {
            let col: Vec<f64> = ds.data.col(j).collect();
            let r1 = autocorr(&col, 1);
            // Fleishman shaping perturbs autocorrelation slightly.
            assert!(
                (r1 - cfg.ar_coeff).abs() < 0.08,
                "signal {j}: lag-1 autocorr {r1} vs target {}",
                cfg.ar_coeff
            );
        }
    }

    #[test]
    fn cross_correlation_matches_target() {
        let cfg = big_cfg();
        let ds = synthesize(&cfg, 13);
        let mut sum = 0.0;
        let mut cnt = 0;
        for a in 0..cfg.n_signals {
            for b in a + 1..cfg.n_signals {
                let ca: Vec<f64> = ds.data.col(a).collect();
                let cb: Vec<f64> = ds.data.col(b).collect();
                sum += pearson(&ca, &cb);
                cnt += 1;
            }
        }
        let mean_rho = sum / cnt as f64;
        assert!(
            (mean_rho - cfg.cross_corr).abs() < 0.08,
            "mean cross-corr {mean_rho} vs target {}",
            cfg.cross_corr
        );
    }

    #[test]
    fn shaped_moments_skew_kurt() {
        let cfg = TpssConfig {
            skewness: 0.7,
            kurtosis: 4.5,
            n_obs: 60_000,
            n_signals: 3,
            noise_frac: 1.0,
            ar_coeff: 0.0, // iid so the marginal shape is exact
            cross_corr: 0.0,
            ..TpssConfig::default()
        };
        let ds = synthesize(&cfg, 5);
        for j in 0..cfg.n_signals {
            let col: Vec<f64> = ds.data.col(j).collect();
            let m = moments(&col);
            assert!((m.skewness - 0.7).abs() < 0.15, "skew={}", m.skewness);
            assert!((m.kurtosis - 4.5).abs() < 0.5, "kurt={}", m.kurtosis);
        }
    }

    #[test]
    fn archetypes_produce_distinct_spectra() {
        // Thermal should have much higher lag-1 autocorrelation than
        // Electrical (slow drift vs fast oscillation).
        let mk = |arch| {
            let cfg = TpssConfig {
                archetype: arch,
                noise_frac: 0.0,
                n_signals: 1,
                n_obs: 4096,
                ..TpssConfig::default()
            };
            let ds = synthesize(&cfg, 3);
            let col: Vec<f64> = ds.data.col(0).collect();
            autocorr(&col, 1)
        };
        let thermal = mk(Archetype::Thermal);
        let electrical = mk(Archetype::Electrical);
        assert!(
            thermal > electrical + 0.2,
            "thermal={thermal} electrical={electrical}"
        );
    }

    #[test]
    fn fault_injection_ground_truth() {
        let cfg = TpssConfig::sized(3, 1000);
        let mut ds = synthesize(&cfg, 21);
        let clean = ds.clone();
        let start = inject(&mut ds, 1, Fault::Step { magnitude: 5.0 }, 0.5, 1);
        assert_eq!(start, 500);
        // before start: untouched; after: shifted by 5σ
        for i in 0..start {
            assert_eq!(ds.data[(i, 1)], clean.data[(i, 1)]);
        }
        for i in start..1000 {
            assert!((ds.data[(i, 1)] - clean.data[(i, 1)] - 5.0 * cfg.sigma).abs() < 1e-12);
        }
        // other signals untouched
        for i in 0..1000 {
            assert_eq!(ds.data[(i, 0)], clean.data[(i, 0)]);
            assert_eq!(ds.data[(i, 2)], clean.data[(i, 2)]);
        }
    }

    #[test]
    fn stuck_fault_freezes_signal() {
        let cfg = TpssConfig::sized(2, 200);
        let mut ds = synthesize(&cfg, 23);
        let start = inject(&mut ds, 0, Fault::Stuck, 0.25, 2);
        let frozen = ds.data[(start, 0)];
        for i in start..200 {
            assert_eq!(ds.data[(i, 0)], frozen);
        }
    }
}
