//! A raw-socket client round-trip against the scoping service.
//!
//! Boots an in-process `containerstress serve` instance on an ephemeral
//! loopback port (native backend, so no artifacts are needed), then talks
//! to it exactly as an external customer would — hand-written HTTP/1.1
//! over `TcpStream`:
//!
//! 1. `POST /v1/scope` — submit a workload + SLA, receive a job id;
//! 2. `GET /v1/jobs/{id}` — poll until the sweep completes;
//! 3. `GET /v1/recommendations/{id}` — fetch the cloud-shape table;
//! 4. repeat the same scope request and watch `/metrics` report it served
//!    from the cell-level sweep cache (zero new trials).
//!
//! Run: `cargo run --release --example service_client`
//!
//! Point it at an already-running server instead with
//! `--addr HOST:PORT` (skips the in-process boot).

use containerstress::config::Config;
use containerstress::coordinator::Backend;
use containerstress::service::Server;
use containerstress::util::cli::Args;
use containerstress::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Minimal HTTP/1.1 exchange: one request, one connection.
fn http(addr: &str, method: &str, path: &str, body: &str) -> anyhow::Result<(u16, Json)> {
    let mut stream = TcpStream::connect(addr)?;
    let raw = format!(
        "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(raw.as_bytes())?;
    let mut out = String::new();
    stream.read_to_string(&mut out)?;
    let status: u16 = out
        .split_whitespace()
        .nth(1)
        .ok_or_else(|| anyhow::anyhow!("bad response: {out}"))?
        .parse()?;
    let payload = out.split("\r\n\r\n").nth(1).unwrap_or("");
    let json = if payload.is_empty() {
        Json::Null
    } else {
        Json::parse(payload).map_err(|e| anyhow::anyhow!("bad body: {e}"))?
    };
    Ok((status, json))
}

const SCOPE_BODY: &str = r#"{
  "sweep": {"signals": [2, 3], "memvecs": [8, 12, 16], "obs": [16, 32],
            "trials": 1, "seed": 11, "model": "mset2"},
  "workload": {"signals": 20, "memvecs": 64, "obs_per_sec": 1.0, "train_window": 4096},
  "sla": {"headroom": 2.0, "max_train_s": 3600.0}
}"#;

fn scope_once(addr: &str) -> anyhow::Result<u64> {
    let (status, j) = http(addr, "POST", "/v1/scope", SCOPE_BODY)?;
    anyhow::ensure!(status == 202, "scope submit: HTTP {status}: {j}");
    let id = j.req("job_id")?.as_f64().unwrap_or(0.0) as u64;
    println!("submitted scope job {id}");
    loop {
        let (_, j) = http(addr, "GET", &format!("/v1/jobs/{id}"), "")?;
        match j.req("status")?.as_str() {
            Some("done") => break,
            Some("failed") => anyhow::bail!("job {id} failed: {j}"),
            _ => std::thread::sleep(Duration::from_millis(50)),
        }
    }
    println!("job {id} done");
    Ok(id)
}

fn main() -> anyhow::Result<()> {
    containerstress::util::logger::init();
    let args = Args::from_env();

    // In-process server unless the caller points us at a live one.
    let (_server, addr) = match args.get("addr") {
        Some(a) => (None, a.to_string()),
        None => {
            let mut cfg = Config {
                backend: "native".into(),
                ..Config::default()
            };
            cfg.service.port = 0;
            cfg.service.cache_dir = None;
            let server = Server::start(&cfg, Backend::Native)?;
            let addr = server.addr().to_string();
            println!("booted in-process service at http://{addr}");
            (Some(server), addr)
        }
    };

    let (_, health) = http(&addr, "GET", "/healthz", "")?;
    println!("healthz: {health}");

    // First scope request: a full Monte Carlo measurement.
    let id = scope_once(&addr)?;
    let (status, rec) = http(&addr, "GET", &format!("/v1/recommendations/{id}"), "")?;
    anyhow::ensure!(status == 200, "recommendation: HTTP {status}: {rec}");
    println!("\n{}", rec.req("rendered")?.as_str().unwrap_or(""));

    // Identical second request: served from the cell-level sweep cache.
    scope_once(&addr)?;
    let (_, metrics) = http(&addr, "GET", "/metrics", "")?;
    let counters = metrics.req("counters")?;
    let hits = counters
        .get("sweep.cache.hits")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    let trials = counters
        .get("sweep.trials")
        .and_then(Json::as_f64)
        .unwrap_or(0.0);
    println!("sweep cache hits: {hits} (trials executed in total: {trials})");
    println!("→ the repeat request re-used every measured cell: no re-measurement");
    Ok(())
}
