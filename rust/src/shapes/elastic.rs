//! Elasticity simulator — single-tenant front of the fleet engine.
//!
//! The paper's introduction motivates ContainerStress with exactly this
//! trade-off: *"Ideally, it would be nice to let a customer start small and
//! autonomously grow their cloud container capabilities through
//! 'elasticity' as compute dynamics dictate. However, in practice that
//! flexibility is not as smooth as cloud marketing teams might wish."*
//!
//! This module quantifies that claim: given a workload-growth trace, it
//! simulates (a) a **pre-scoped fixed shape** (what the ContainerStress
//! recommendation buys up front) against (b) a **reactive autoscaler**
//! that climbs the shape ladder when utilisation crosses a threshold —
//! paying a scale-up lag (SLA violations while saturated) and a migration
//! cost (retraining/transfer) on every step. Output: cost-over-time,
//! violation counts, and the crossover where pre-scoping wins.
//!
//! The simulation loops themselves live in [`crate::scenario::fleet`],
//! which generalises them from one tenant to trace-driven fleets with
//! pluggable policies; this module keeps the original single-tenant API
//! (and its semantics, bit for bit) as thin wrappers over that engine.

use super::Shape;
use crate::scenario::fleet;

/// Workload intensity over time: per-epoch demand expressed as the
/// *fraction of a reference shape's capacity* (1 core-equivalent unit).
///
/// Validated at construction: every epoch demand must be finite and
/// non-negative, and the epoch length positive — a `NaN` smuggled into a
/// trace would otherwise silently disable every utilisation comparison
/// downstream (`NaN > cap` is `false`, so violations vanish).
#[derive(Clone, Debug, PartialEq)]
pub struct GrowthTrace {
    /// Demand per epoch, in core-equivalents (validated).
    demand: Vec<f64>,
    /// Wall-clock hours per epoch (validated).
    hours_per_epoch: f64,
}

/// Why a [`GrowthTrace`] was rejected at construction.
#[derive(Clone, Debug, PartialEq, thiserror::Error)]
pub enum TraceError {
    /// The demand vector was empty.
    #[error("growth trace has no epochs")]
    Empty,
    /// `hours_per_epoch` was non-finite or not positive.
    #[error("hours_per_epoch must be finite and > 0, got {0}")]
    BadHours(f64),
    /// An epoch's demand was `NaN`, infinite, or negative.
    #[error("demand at epoch {epoch} must be finite and ≥ 0, got {value}")]
    BadDemand {
        /// Index of the offending epoch.
        epoch: usize,
        /// The rejected demand value.
        value: f64,
    },
}

impl GrowthTrace {
    /// Validated constructor: rejects empty traces, non-positive epoch
    /// lengths, and `NaN`/infinite/negative demand values with a typed
    /// error instead of silently accepting them.
    pub fn new(demand: Vec<f64>, hours_per_epoch: f64) -> Result<GrowthTrace, TraceError> {
        if demand.is_empty() {
            return Err(TraceError::Empty);
        }
        if !hours_per_epoch.is_finite() || hours_per_epoch <= 0.0 {
            return Err(TraceError::BadHours(hours_per_epoch));
        }
        for (epoch, &value) in demand.iter().enumerate() {
            if !value.is_finite() || value < 0.0 {
                return Err(TraceError::BadDemand { epoch, value });
            }
        }
        Ok(GrowthTrace {
            demand,
            hours_per_epoch,
        })
    }

    /// Exponential customer growth: `d0 · g^t` for `epochs` epochs.
    pub fn exponential(
        d0: f64,
        growth_per_epoch: f64,
        epochs: usize,
        hours: f64,
    ) -> Result<GrowthTrace, TraceError> {
        GrowthTrace::new(
            (0..epochs)
                .map(|t| d0 * growth_per_epoch.powi(t as i32))
                .collect(),
            hours,
        )
    }

    /// Step growth: demand doubles at each given epoch index.
    pub fn steps(
        d0: f64,
        step_epochs: &[usize],
        epochs: usize,
        hours: f64,
    ) -> Result<GrowthTrace, TraceError> {
        let mut demand = Vec::with_capacity(epochs);
        let mut d = d0;
        for t in 0..epochs {
            if step_epochs.contains(&t) {
                d *= 2.0;
            }
            demand.push(d);
        }
        GrowthTrace::new(demand, hours)
    }

    /// Demand per epoch, in core-equivalents.
    pub fn demand(&self) -> &[f64] {
        &self.demand
    }

    /// Wall-clock hours per epoch.
    pub fn hours_per_epoch(&self) -> f64 {
        self.hours_per_epoch
    }

    /// Number of epochs in the trace.
    pub fn epochs(&self) -> usize {
        self.demand.len()
    }

    /// Largest epoch demand (0.0 for an all-zero trace).
    pub fn peak(&self) -> f64 {
        self.demand.iter().cloned().fold(0.0, f64::max)
    }
}

/// Autoscaler policy.
#[derive(Clone, Copy, Debug)]
pub struct ElasticPolicy {
    /// Scale up when utilisation exceeds this.
    pub scale_up_at: f64,
    /// Scale down when utilisation drops below this.
    pub scale_down_at: f64,
    /// Epochs of lag before a scale-up takes effect (provisioning +
    /// retraining); demand above capacity during the lag violates SLA.
    pub scale_lag_epochs: usize,
    /// One-off cost per migration (USD — data transfer + retraining time).
    pub migration_usd: f64,
}

impl Default for ElasticPolicy {
    fn default() -> Self {
        ElasticPolicy {
            scale_up_at: 0.8,
            scale_down_at: 0.3,
            scale_lag_epochs: 2,
            migration_usd: 5.0,
        }
    }
}

/// Result of one strategy simulation.
#[derive(Clone, Debug)]
pub struct ElasticOutcome {
    /// Total spend over the trace (USD).
    pub total_usd: f64,
    /// Epochs in which demand exceeded provisioned capacity.
    pub violation_epochs: usize,
    /// Number of shape migrations performed.
    pub migrations: usize,
    /// Shape name per epoch (for reporting).
    pub shape_trace: Vec<&'static str>,
}

/// Simulate a fixed, pre-scoped shape over the trace.
pub fn simulate_fixed(shape: &Shape, trace: &GrowthTrace) -> ElasticOutcome {
    fleet::run_fixed(shape, trace).outcome
}

/// Simulate the reactive autoscaler over the trace.
pub fn simulate_elastic(policy: &ElasticPolicy, trace: &GrowthTrace) -> ElasticOutcome {
    fleet::run_reactive(policy, trace).outcome
}

/// Side-by-side comparison used by reports: returns (fixed, elastic) for a
/// pre-scoped shape chosen to cover the trace's *final* demand — the
/// ContainerStress recommendation.
pub fn compare(trace: &GrowthTrace, policy: &ElasticPolicy) -> (ElasticOutcome, ElasticOutcome) {
    let scoped = fleet::prescope_shape(trace, fleet::PRESCOPE_HEADROOM);
    (
        simulate_fixed(scoped, trace),
        simulate_elastic(policy, trace),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_shape_covering_peak_never_violates() {
        // growth kept inside the catalog's largest CPU shape (~35 core-eq)
        let trace = GrowthTrace::exponential(0.5, 1.04, 80, 24.0).unwrap();
        let (fixed, _) = compare(&trace, &ElasticPolicy::default());
        assert_eq!(fixed.violation_epochs, 0);
        assert_eq!(fixed.migrations, 0);
    }

    #[test]
    fn elastic_violates_during_scale_lag() {
        // Paper's point: elasticity "is not as smooth" — a fast-growing
        // workload outruns the scale-up lag and takes SLA hits.
        let trace = GrowthTrace::steps(0.5, &[10, 20, 30], 60, 24.0).unwrap();
        let elastic = simulate_elastic(&ElasticPolicy::default(), &trace);
        assert!(
            elastic.violation_epochs > 0,
            "step growth must violate during lag"
        );
        assert!(elastic.migrations >= 3);
    }

    #[test]
    fn elastic_cheaper_for_slow_growth() {
        // A workload that stays small for most of its life: paying for the
        // peak-scoped shape the whole time costs more.
        let trace = GrowthTrace::exponential(0.3, 1.02, 200, 24.0).unwrap();
        let (fixed, elastic) = compare(&trace, &ElasticPolicy::default());
        assert!(
            elastic.total_usd < fixed.total_usd,
            "elastic {:.0} vs fixed {:.0}",
            elastic.total_usd,
            fixed.total_usd
        );
    }

    #[test]
    fn fixed_wins_on_violations_elastic_on_cost() {
        let trace = GrowthTrace::steps(0.4, &[5, 15, 25], 50, 24.0).unwrap();
        let (fixed, elastic) = compare(&trace, &ElasticPolicy::default());
        assert_eq!(fixed.violation_epochs, 0);
        assert!(elastic.violation_epochs > 0);
        assert!(elastic.total_usd < fixed.total_usd);
    }

    #[test]
    fn scale_down_happens() {
        let mut demand = vec![8.0; 20];
        demand.extend(vec![0.5; 40]);
        let trace = GrowthTrace::new(demand, 24.0).unwrap();
        let elastic = simulate_elastic(&ElasticPolicy::default(), &trace);
        let last = elastic.shape_trace.last().unwrap();
        let first_big = elastic.shape_trace[5];
        assert_ne!(last, &first_big, "autoscaler never scaled down");
    }

    #[test]
    fn trace_generators() {
        let e = GrowthTrace::exponential(1.0, 2.0, 4, 1.0).unwrap();
        assert_eq!(e.demand(), &[1.0, 2.0, 4.0, 8.0]);
        let s = GrowthTrace::steps(1.0, &[2], 4, 1.0).unwrap();
        assert_eq!(s.demand(), &[1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn trace_validation_rejects_bad_demand() {
        assert_eq!(GrowthTrace::new(vec![], 24.0), Err(TraceError::Empty));
        assert_eq!(
            GrowthTrace::new(vec![1.0], 0.0),
            Err(TraceError::BadHours(0.0))
        );
        assert!(matches!(
            GrowthTrace::new(vec![1.0, f64::NAN], 24.0),
            Err(TraceError::BadDemand { epoch: 1, .. })
        ));
        assert_eq!(
            GrowthTrace::new(vec![0.5, -0.1], 24.0),
            Err(TraceError::BadDemand {
                epoch: 1,
                value: -0.1
            })
        );
        // constructor paths validate too: a NaN seed demand is caught
        assert!(GrowthTrace::exponential(f64::NAN, 1.1, 4, 24.0).is_err());
        assert!(GrowthTrace::steps(1.0, &[1], 4, f64::INFINITY).is_err());
        // zero demand is allowed (an idle tenant is a valid scenario)
        assert!(GrowthTrace::new(vec![0.0; 4], 24.0).is_ok());
    }
}
