//! The **pluggable ML prognostic interface** (§II.B of the paper):
//! "we have architected ContainerStress to support pluggable ML algorithms
//! so that other conventional forms of ML services … will also be easily
//! evaluated".
//!
//! [`PrognosticModel`] is that plug point. Three implementations ship:
//!
//! - [`MsetPlugin`] — the paper's primary technique (wraps [`crate::mset`]);
//! - [`AakrPlugin`] — Auto-Associative Kernel Regression, the first
//!   alternative the paper names;
//! - [`RidgePlugin`] — per-signal linear ridge regression, a cheap linear
//!   baseline that bounds the nonlinear methods from below.
//!
//! The sweep engine and the scoping recommender only see this trait, so a
//! new algorithm is scoped across cloud shapes by implementing four methods.

pub mod nn;
pub mod svr;

pub use nn::MlpPlugin;
pub use svr::SvrPlugin;

use crate::linalg::{kernel, solve_spd, Mat, Workspace};
use crate::mset::{self, Estimate, MsetModel, Scaler};

/// A trainable prognostic estimator of sensor state.
pub trait PrognosticModel: Send + Sync {
    /// Short identifier used in reports and CSV output.
    fn name(&self) -> &'static str;

    /// Train on raw observations (rows = observations). `m` is the memory /
    /// capacity parameter — memory vectors for kernel methods, ignored by
    /// parametric ones.
    fn fit(&mut self, x_train: &Mat, m: usize) -> anyhow::Result<()>;

    /// Estimate a chunk of raw observations; returns scaled-unit estimates
    /// and residuals.
    fn estimate(&self, x: &Mat) -> Estimate;

    /// Approximate training FLOP count for the accelerator model.
    fn train_flops(&self, n: usize, m: usize) -> f64;

    /// Approximate per-observation surveillance FLOP count.
    fn surveil_flops_per_obs(&self, n: usize, m: usize) -> f64;
}

// ---------------------------------------------------------------- MSET2 ----

/// MSET2 as a plug-in (delegates to [`crate::mset`]).
#[derive(Default)]
pub struct MsetPlugin {
    model: Option<MsetModel>,
}

impl PrognosticModel for MsetPlugin {
    fn name(&self) -> &'static str {
        "mset2"
    }

    fn fit(&mut self, x_train: &Mat, m: usize) -> anyhow::Result<()> {
        self.model = Some(mset::train(x_train, m)?);
        Ok(())
    }

    fn estimate(&self, x: &Mat) -> Estimate {
        self.model.as_ref().expect("fit first").surveil(x)
    }

    fn train_flops(&self, n: usize, m: usize) -> f64 {
        let (n, m) = (n as f64, m as f64);
        // similarity matrix m²·(3n) + eigendecomposition ~ 9m³ + pinv 2m³
        3.0 * n * m * m + 11.0 * m * m * m
    }

    fn surveil_flops_per_obs(&self, n: usize, m: usize) -> f64 {
        let (n, m) = (n as f64, m as f64);
        // similarity m·3n + weights m² (G·k) + estimate m·n
        3.0 * n * m + 2.0 * m * m + 2.0 * m * n
    }
}

// ----------------------------------------------------------------- AAKR ----

/// Auto-Associative Kernel Regression: the estimate is the similarity-
/// weighted average of the memory vectors (no trained inverse).
pub struct AakrPlugin {
    d: Option<Mat>,
    scaler: Option<Scaler>,
}

impl Default for AakrPlugin {
    fn default() -> Self {
        AakrPlugin {
            d: None,
            scaler: None,
        }
    }
}

impl PrognosticModel for AakrPlugin {
    fn name(&self) -> &'static str {
        "aakr"
    }

    fn fit(&mut self, x_train: &Mat, m: usize) -> anyhow::Result<()> {
        anyhow::ensure!(m <= x_train.rows, "m exceeds observations");
        let scaler = Scaler::fit(x_train);
        let xs = scaler.transform(x_train);
        let idx = mset::select_memory(&xs, m);
        let mut d = Mat::zeros(m, x_train.cols);
        for (r, &i) in idx.iter().enumerate() {
            d.row_mut(r).copy_from_slice(xs.row(i));
        }
        self.d = Some(d);
        self.scaler = Some(scaler);
        Ok(())
    }

    fn estimate(&self, x: &Mat) -> Estimate {
        let d = self.d.as_ref().expect("fit first");
        Workspace::with(|ws| {
            let mut xs = Mat {
                rows: 0,
                cols: 0,
                data: ws.take_f64(0),
            };
            self.scaler.as_ref().unwrap().transform_into(x, &mut xs);
            // Kᵀ = sim(X, D) : B × m — each observation's weight row is
            // contiguous, so normalisation and the weighted sum both
            // stream; X̂ = norm(Kᵀ)·D is one blocked product.
            let mut kt = Mat {
                rows: 0,
                cols: 0,
                data: ws.take_f64(0),
            };
            mset::sim_cross_t_into(&mut kt, &xs, d, d.cols, ws);
            for wrow in kt.data.chunks_exact_mut(d.rows.max(1)) {
                let wsum: f64 = wrow.iter().sum();
                let inv = 1.0 / wsum.max(1e-12);
                for w in wrow.iter_mut() {
                    *w *= inv;
                }
            }
            let mut xhat = Mat::zeros(0, 0);
            kernel::matmul_into(&mut xhat, &kt, d, ws);
            let resid = xs.sub(&xhat);
            ws.give_f64(kt.data);
            ws.give_f64(xs.data);
            Estimate { xhat, resid }
        })
    }

    fn train_flops(&self, n: usize, m: usize) -> f64 {
        // selection only: one norm pass over the candidate set
        2.0 * n as f64 * m as f64
    }

    fn surveil_flops_per_obs(&self, n: usize, m: usize) -> f64 {
        let (n, m) = (n as f64, m as f64);
        // similarity m·3n + normalisation m + weighted sum m·n
        3.0 * n * m + m + 2.0 * m * n
    }
}

// ---------------------------------------------------------------- Ridge ----

/// Per-signal linear ridge regression: each signal is predicted from all
/// others by a linear model fit on the training window.
pub struct RidgePlugin {
    /// `n × n` coefficient matrix, row j = weights predicting signal j
    /// (with coef[j][j] = 0), plus intercept handling via scaled space.
    coef: Option<Mat>,
    scaler: Option<Scaler>,
    /// Ridge strength.
    pub alpha: f64,
}

impl Default for RidgePlugin {
    fn default() -> Self {
        RidgePlugin {
            coef: None,
            scaler: None,
            alpha: 1e-2,
        }
    }
}

impl PrognosticModel for RidgePlugin {
    fn name(&self) -> &'static str {
        "ridge"
    }

    fn fit(&mut self, x_train: &Mat, _m: usize) -> anyhow::Result<()> {
        let scaler = Scaler::fit(x_train);
        let xs = scaler.transform(x_train);
        let n = xs.cols;
        // Gram matrix XᵀX once (a blocked syrk over Xᵀ — exactly
        // symmetric), then per-signal system with the target column/row
        // zeroed out.
        let gram = Workspace::with(|ws| {
            let mut xt = Mat {
                rows: 0,
                cols: 0,
                data: ws.take_f64(0),
            };
            xs.transpose_into(&mut xt);
            let mut gram = Mat::zeros(0, 0);
            kernel::syrk_into(&mut gram, &xt);
            ws.give_f64(xt.data);
            gram
        });
        let mut coef = Mat::zeros(n, n);
        for j in 0..n {
            // A = gram over features != j (+ αI), b = Xᵀ x_j over same
            let feats: Vec<usize> = (0..n).filter(|&f| f != j).collect();
            let mut a = Mat::zeros(n - 1, n - 1);
            let mut rhs = vec![0.0; n - 1];
            for (r, &fr) in feats.iter().enumerate() {
                rhs[r] = gram[(fr, j)];
                for (c, &fc) in feats.iter().enumerate() {
                    a[(r, c)] = gram[(fr, fc)];
                }
                a[(r, r)] += self.alpha * xs.rows as f64;
            }
            let w = solve_spd(&a, &rhs)
                .ok_or_else(|| anyhow::anyhow!("ridge system not SPD"))?;
            for (r, &fr) in feats.iter().enumerate() {
                coef[(j, fr)] = w[r];
            }
        }
        self.coef = Some(coef);
        self.scaler = Some(scaler);
        Ok(())
    }

    fn estimate(&self, x: &Mat) -> Estimate {
        let coef = self.coef.as_ref().expect("fit first");
        let xs = self.scaler.as_ref().unwrap().transform(x);
        // X̂ = X · Cᵀ — an NT product over row-major operands, so the
        // blocked kernel needs neither a transposed copy nor packing.
        let xhat = Workspace::with(|ws| {
            let mut xhat = Mat::zeros(0, 0);
            kernel::matmul_nt_into(&mut xhat, &xs, coef, ws);
            xhat
        });
        let resid = xs.sub(&xhat);
        Estimate { xhat, resid }
    }

    fn train_flops(&self, n: usize, _m: usize) -> f64 {
        let n = n as f64;
        // n solves of (n-1)³/3 plus the gram matrix
        n * (n * n * n / 3.0) + 2.0 * n * n
    }

    fn surveil_flops_per_obs(&self, n: usize, _m: usize) -> f64 {
        2.0 * (n * n) as f64
    }
}

/// Construct a plug-in by name (CLI dispatch).
pub fn by_name(name: &str) -> anyhow::Result<Box<dyn PrognosticModel>> {
    match name {
        "mset2" => Ok(Box::new(MsetPlugin::default())),
        "aakr" => Ok(Box::new(AakrPlugin::default())),
        "ridge" => Ok(Box::new(RidgePlugin::default())),
        "mlp" => Ok(Box::new(MlpPlugin::default())),
        "svr" => Ok(Box::new(SvrPlugin::default())),
        other => anyhow::bail!("unknown model '{other}' (try mset2|aakr|ridge|mlp|svr)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tpss::{inject, synthesize, Fault, TpssConfig};

    fn fit_all(n: usize, t: usize, m: usize) -> Vec<Box<dyn PrognosticModel>> {
        let ds = synthesize(&TpssConfig::sized(n, t), 42);
        ["mset2", "aakr", "ridge"]
            .iter()
            .map(|name| {
                let mut p = by_name(name).unwrap();
                p.fit(&ds.data, m).unwrap();
                p
            })
            .collect()
    }

    #[test]
    fn all_plugins_fit_and_estimate() {
        let plugins = fit_all(6, 1500, 32);
        let probe = synthesize(&TpssConfig::sized(6, 100), 43);
        for p in &plugins {
            let est = p.estimate(&probe.data);
            assert_eq!(est.xhat.rows, 100);
            assert_eq!(est.xhat.cols, 6);
            assert!(est.resid.data.iter().all(|v| v.is_finite()), "{}", p.name());
        }
    }

    #[test]
    fn all_plugins_detect_gross_fault() {
        let plugins = fit_all(6, 1500, 32);
        let cfg = TpssConfig::sized(6, 400);
        let healthy = synthesize(&cfg, 44);
        let mut faulted = synthesize(&cfg, 44);
        inject(&mut faulted, 3, Fault::Step { magnitude: 8.0 }, 0.0, 9);
        for p in &plugins {
            let rh = p.estimate(&healthy.data).resid.norm();
            let rf = p.estimate(&faulted.data).resid.norm();
            assert!(
                rf > 1.5 * rh,
                "{}: fault residual {rf} vs healthy {rh}",
                p.name()
            );
        }
    }

    #[test]
    fn flop_models_monotone() {
        let plugins: Vec<Box<dyn PrognosticModel>> = vec![
            Box::new(MsetPlugin::default()),
            Box::new(AakrPlugin::default()),
            Box::new(RidgePlugin::default()),
        ];
        for p in &plugins {
            assert!(p.train_flops(16, 128) > p.train_flops(8, 64));
            assert!(
                p.surveil_flops_per_obs(16, 128) >= p.surveil_flops_per_obs(8, 64),
                "{}",
                p.name()
            );
        }
    }

    #[test]
    fn unknown_name_rejected() {
        assert!(by_name("svm").is_err());
    }

    #[test]
    fn mset_beats_ridge_on_nonlinear_data() {
        // Kernel methods should track the nonlinear manifold better than a
        // linear model on held-out healthy data.
        let cfg = TpssConfig {
            n_signals: 5,
            n_obs: 3000,
            noise_frac: 0.15,
            ..TpssConfig::default()
        };
        let train = synthesize(&cfg, 50);
        let test = synthesize(
            &TpssConfig {
                n_obs: 500,
                ..cfg.clone()
            },
            51,
        );
        let mut mset = MsetPlugin::default();
        mset.fit(&train.data, 128).unwrap();
        let mut ridge = RidgePlugin::default();
        ridge.fit(&train.data, 128).unwrap();
        let r_mset = mset.estimate(&test.data).resid.norm();
        let r_ridge = ridge.estimate(&test.data).resid.norm();
        assert!(
            r_mset < r_ridge * 1.5,
            "mset {r_mset} should be competitive with ridge {r_ridge}"
        );
    }
}
