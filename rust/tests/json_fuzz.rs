//! Deterministic fuzz harness for the incremental JSON wire layer.
//!
//! A seeded corpus of valid and malformed documents is mutated with
//! byte-level edits (insert/delete/replace/duplicate/truncate/splice) and
//! every resulting input is pushed through [`StreamParser`]:
//!
//! - **no panics** — every parse runs under `catch_unwind`;
//! - **bounded memory** — `buffered_bytes()` never exceeds the token
//!   limit and `depth()` never exceeds the nesting limit;
//! - **incremental ≡ batch** — the streaming parser accepts exactly the
//!   same documents as [`Json::parse`] and yields the same value;
//! - **chunking invariance** — re-feeding the same bytes split at every
//!   (sampled) chunk boundary, and byte-at-a-time for short inputs,
//!   produces the same value-or-error outcome as a single feed.
//!
//! The run is deterministic: `CS_FUZZ_SEED` picks the mutation stream
//! (default fixed) and `CS_FUZZ_ITERS` scales the iteration count (CI runs
//! a larger budget than the default `cargo test`). On failure the harness
//! greedily minimises the input and writes it to
//! `results/json_fuzz_min.bin` so CI can upload it as an artifact.

use containerstress::util::json::stream::{Limits, StreamParser, ValueBuilder};
use containerstress::util::json::Json;
use containerstress::util::rng::Rng;
use std::panic::{catch_unwind, AssertUnwindSafe};

/// Default per-`cargo test` iteration budget; CI raises it via env.
const DEFAULT_ITERS: usize = 1500;

fn iters() -> usize {
    std::env::var("CS_FUZZ_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(DEFAULT_ITERS)
}

fn seed() -> u64 {
    std::env::var("CS_FUZZ_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xF477_C0DE)
}

/// Seed corpus: small valid documents, every token kind, boundary-hostile
/// escapes, and a spread of malformed inputs the parser must reject
/// without panicking.
fn corpus() -> Vec<Vec<u8>> {
    let seeds: &[&str] = &[
        // valid
        "null",
        "true",
        "false",
        "0",
        "-0",
        "42",
        "-17",
        "123.456",
        "1e9",
        "-2.5E-3",
        "6.02e+23",
        "\"\"",
        "\"abc\"",
        "\"a\\\"b\\\\c\\/d\\n\\t\\r\\f\\b\"",
        "\"\\u00e9\\u0418\\u4e2d\"",
        "\"\\ud83d\\ude00\"",
        "[]",
        "[1]",
        "[1,2,3]",
        "[[],[[]],[1,[2,[3]]]]",
        "{}",
        "{\"a\":1}",
        "{\"a\":{\"b\":{\"c\":[null,true,\"x\"]}},\"d\":-1.5e2}",
        " { \"k\" : [ 1 , 2 ] } ",
        "{\"dup\":1,\"dup\":2}",
        // malformed
        "",
        "   ",
        "{",
        "}",
        "[",
        "]",
        "[1,",
        "[1,]",
        "{\"a\"}",
        "{\"a\":}",
        "{\"a\":1,}",
        "{1:2}",
        "[1 2]",
        "01",
        "+1",
        "--1",
        "1..2",
        "1e",
        "1e+",
        ".5",
        "-",
        "tru",
        "truee",
        "nul",
        "falsey",
        "\"unterminated",
        "\"bad\\escape\"",
        "\"\\u12\"",
        "\"\\ud800\"",
        "[1,2] trailing",
        "null null",
    ];
    let mut out: Vec<Vec<u8>> = seeds.iter().map(|s| s.as_bytes().to_vec()).collect();
    // a couple of non-UTF-8 inputs: must be rejected, never panic
    out.push(vec![0xff, 0xfe, b'1']);
    out.push(vec![b'"', 0xc3, b'"']);
    out
}

/// Bytes mutations are biased toward, so edits tend to stay JSON-shaped.
const ALPHABET: &[u8] = b"{}[],:\"\\0123456789.eE+-truefalsn u\t\n\r ";

fn mutate(rng: &mut Rng, base: &[u8], corpus: &[Vec<u8>]) -> Vec<u8> {
    let mut v = base.to_vec();
    for _ in 0..1 + rng.below(4) {
        let pick = |rng: &mut Rng| {
            if rng.below(4) == 0 {
                rng.below(256) as u8
            } else {
                ALPHABET[rng.range_usize(0, ALPHABET.len())]
            }
        };
        match rng.below(6) {
            0 => {
                let at = rng.range_usize(0, v.len() + 1);
                let b = pick(rng);
                v.insert(at, b);
            }
            1 if !v.is_empty() => {
                v.remove(rng.range_usize(0, v.len()));
            }
            2 if !v.is_empty() => {
                let at = rng.range_usize(0, v.len());
                v[at] = pick(rng);
            }
            3 if !v.is_empty() => {
                // duplicate a random slice in place
                let a = rng.range_usize(0, v.len());
                let b = rng.range_usize(a, v.len().min(a + 16) + 1);
                let slice = v[a..b].to_vec();
                let at = rng.range_usize(0, v.len() + 1);
                v.splice(at..at, slice);
            }
            4 if !v.is_empty() => {
                v.truncate(rng.range_usize(0, v.len() + 1));
            }
            _ => {
                // splice a fragment of another corpus entry
                let other = &corpus[rng.range_usize(0, corpus.len())];
                if !other.is_empty() {
                    let a = rng.range_usize(0, other.len());
                    let b = rng.range_usize(a, other.len().min(a + 16) + 1);
                    let at = rng.range_usize(0, v.len() + 1);
                    v.splice(at..at, other[a..b].iter().copied());
                }
            }
        }
        if v.len() > 4096 {
            v.truncate(4096);
        }
    }
    v
}

/// Incremental parse with the memory-bound assertions inlined: returns the
/// value, or `Err(())` for any reject (offsets/messages are not compared —
/// only accept/reject and the value must match the batch parser).
fn incremental(chunks: &[&[u8]], limits: Limits) -> Result<Json, ()> {
    let mut parser = StreamParser::new(limits);
    let mut builder = ValueBuilder::new();
    let mut events = Vec::new();
    for chunk in chunks {
        if parser.feed(chunk, &mut events).is_err() {
            return Err(());
        }
        assert!(
            parser.buffered_bytes() <= limits.max_token_bytes,
            "token buffer exceeded its limit: {} > {}",
            parser.buffered_bytes(),
            limits.max_token_bytes
        );
        assert!(
            parser.depth() <= limits.max_depth,
            "nesting exceeded its limit: {} > {}",
            parser.depth(),
            limits.max_depth
        );
        for ev in events.drain(..) {
            if builder.on_event(ev).is_err() {
                return Err(());
            }
        }
    }
    if parser.finish(&mut events).is_err() {
        return Err(());
    }
    for ev in events.drain(..) {
        if builder.on_event(ev).is_err() {
            return Err(());
        }
    }
    builder.take().ok_or(())
}

/// The full per-input check. Panics (with context) on any violation.
fn check_input(input: &[u8]) {
    let limits = Limits::lenient();
    let whole = catch_unwind(AssertUnwindSafe(|| incremental(&[input], limits)))
        .unwrap_or_else(|_| {
            panic!(
                "streaming parser panicked on {:?}",
                String::from_utf8_lossy(input)
            )
        });

    // incremental ≡ batch (UTF-8 inputs only — the batch parser takes &str)
    if let Ok(text) = std::str::from_utf8(input) {
        match (Json::parse(text), &whole) {
            (Ok(b), Ok(s)) => assert_eq!(
                &b, s,
                "batch and streaming values differ for {text:?}"
            ),
            (Ok(_), Err(())) => panic!("batch accepts, streaming rejects: {text:?}"),
            (Err(_), Ok(_)) => panic!("batch rejects, streaming accepts: {text:?}"),
            (Err(_), Err(())) => {}
        }
    } else {
        assert!(whole.is_err(), "non-UTF-8 input must be rejected");
    }

    // chunking invariance: every (sampled) 2-part split ...
    let n = input.len();
    let step = (n / 64).max(1);
    let mut at = 1;
    while at < n {
        let split = incremental(&[&input[..at], &input[at..]], limits);
        assert_eq!(
            split, whole,
            "outcome changed when split at byte {at} of {:?}",
            String::from_utf8_lossy(input)
        );
        at += step;
    }
    // ... and byte-at-a-time for short inputs
    if n > 0 && n <= 64 {
        let singles: Vec<&[u8]> = input.chunks(1).collect();
        assert_eq!(
            incremental(&singles, limits),
            whole,
            "outcome changed when fed byte-at-a-time: {:?}",
            String::from_utf8_lossy(input)
        );
    }
}

/// Run `check_input` and capture a failure instead of unwinding, so the
/// driver can minimise before reporting.
fn failure(input: &[u8]) -> Option<String> {
    catch_unwind(AssertUnwindSafe(|| check_input(input)))
        .err()
        .map(|e| {
            e.downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "non-string panic payload".into())
        })
}

/// Greedy minimisation: repeatedly drop slices while the input still
/// fails. Runs with a silent panic hook so the search doesn't spam stderr.
fn minimise(input: &[u8]) -> Vec<u8> {
    let prev_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(|_| {}));
    let mut cur = input.to_vec();
    let mut window = (cur.len() / 2).max(1);
    while window >= 1 {
        let mut progressed = false;
        let mut at = 0;
        while at < cur.len() {
            let mut candidate = cur.clone();
            candidate.drain(at..(at + window).min(candidate.len()));
            if failure(&candidate).is_some() {
                cur = candidate;
                progressed = true;
            } else {
                at += window;
            }
        }
        if !progressed {
            if window == 1 {
                break;
            }
            window /= 2;
        }
    }
    std::panic::set_hook(prev_hook);
    cur
}

/// Persist a failing input for CI artifact upload (best-effort).
fn report(original: &[u8], minimised: &[u8], msg: &str) {
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write("results/json_fuzz_min.bin", minimised);
    let _ = std::fs::write(
        "results/json_fuzz_min.txt",
        format!(
            "seed: {}\nfailure: {}\noriginal ({} bytes): {:?}\nminimised ({} bytes): {:?}\n",
            seed(),
            msg,
            original.len(),
            String::from_utf8_lossy(original),
            minimised.len(),
            String::from_utf8_lossy(minimised),
        ),
    );
}

#[test]
fn fuzz_corpus_and_mutations() {
    let corpus = corpus();
    // the unmutated corpus first: these must always hold
    for entry in &corpus {
        if let Some(msg) = failure(entry) {
            let min = minimise(entry);
            report(entry, &min, &msg);
            panic!(
                "corpus input failed ({} bytes minimised to {}, \
                 written to results/json_fuzz_min.bin): {msg}",
                entry.len(),
                min.len()
            );
        }
    }
    // then the seeded mutation stream
    let mut rng = Rng::new(seed());
    for i in 0..iters() {
        let base = &corpus[rng.range_usize(0, corpus.len())];
        let input = mutate(&mut rng, base, &corpus);
        if let Some(msg) = failure(&input) {
            let min = minimise(&input);
            report(&input, &min, &msg);
            panic!(
                "fuzz iteration {i} (seed {}) failed; input minimised \
                 {} → {} bytes, written to results/json_fuzz_min.bin: {msg}",
                seed(),
                input.len(),
                min.len()
            );
        }
    }
}

#[test]
fn deep_nesting_is_rejected_with_bounded_state() {
    // 10k opens against the default 256-depth limit: must error (not
    // recurse or grow without bound) and the bound must hold throughout.
    let input = vec![b'['; 10_000];
    let limits = Limits::default();
    let mut parser = StreamParser::new(limits);
    let mut events = Vec::new();
    let r = parser.feed(&input, &mut events);
    assert!(r.is_err(), "depth limit must reject 10k nested arrays");
    assert!(parser.depth() <= limits.max_depth);
}

#[test]
fn oversized_token_is_rejected_with_bounded_buffer() {
    // A 3 MB string against the default 1 MB token limit, fed in 8 KB
    // chunks like the HTTP layer does: the buffer must never outgrow the
    // limit even though the token spans hundreds of chunks.
    let mut input = vec![b'"'];
    input.extend(std::iter::repeat(b'x').take(3 << 20));
    input.push(b'"');
    let limits = Limits::default();
    let mut parser = StreamParser::new(limits);
    let mut events = Vec::new();
    let mut rejected = false;
    for chunk in input.chunks(8 << 10) {
        if parser.feed(chunk, &mut events).is_err() {
            rejected = true;
            break;
        }
        assert!(
            parser.buffered_bytes() <= limits.max_token_bytes,
            "token buffer exceeded its limit mid-stream"
        );
    }
    assert!(rejected, "token limit must reject a 3 MB string");
}
