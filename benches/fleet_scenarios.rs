//! **BENCH-fleet**: the fleet scenario engine replays from surface
//! oracles — it must never pay for Monte Carlo trials the cache already
//! holds.
//!
//! Two assertions:
//!
//! 1. **Oracle replay speed** — a 1000-tenant × 365-epoch workload-mode
//!    scenario (diurnal cycles, growth, per-tenant jitter, workload drift
//!    across the design grid) replays in **< 1 s** with **zero fresh
//!    Monte Carlo trials**: every per-epoch cost query is answered by the
//!    fitted surfaces or the warm cell cache.
//! 2. **Degenerate-case fidelity** — a single-tenant, jitter-free
//!    scenario built through the JSON spec path reproduces
//!    `shapes::elastic::compare`'s reactive-vs-pre-scoped crossover
//!    **bit-identically** (totals compared via `f64::to_bits`).
//!
//! Output: `results/BENCH_fleet.json` + `results/fleet_scenarios.csv`.
//! `CS_BENCH_QUICK=1` is accepted (and recorded in the JSON) for CI
//! symmetry with the other benches, but changes nothing here: the
//! warm-up sweep is already tiny and the full-scale replay *is* the
//! thing under test.

use containerstress::bench::figs;
use containerstress::coordinator::{run_sweep_cached, Backend, CellStore, SweepSpec};
use containerstress::metrics::Registry;
use containerstress::recommend::PolicyPoint;
use containerstress::report;
use containerstress::scenario::spec::{ArrivalSpec, DemandKind, DemandSpec, WorkloadSpec};
use containerstress::scenario::{run_scenario, Backstop, ScenarioSpec, SurfaceOracle};
use containerstress::service::SweepCache;
use containerstress::shapes::elastic::{compare, ElasticPolicy, GrowthTrace};
use containerstress::shapes::Workload;
use containerstress::util::json::Json;
use std::time::Instant;

const TENANTS: usize = 1000;
const EPOCHS: usize = 365;

/// The oracle's measurement grid: 12 measurable cells, milliseconds per
/// trial on the native backend. Workload drift is kept inside this box so
/// the replay is pure surface math.
fn oracle_sweep() -> SweepSpec {
    SweepSpec {
        signals: vec![2, 3],
        memvecs: vec![8, 12, 16],
        obs: vec![16, 32],
        trials: 1,
        seed: 9,
        model: "mset2".into(),
        workers: 0,
        ..SweepSpec::default()
    }
}

fn fleet_scenario() -> ScenarioSpec {
    ScenarioSpec {
        name: "bench-fleet".into(),
        seed: 11,
        epochs: EPOCHS,
        hours_per_epoch: 24.0,
        arrivals: ArrivalSpec {
            initial: 400,
            rate_per_epoch: 2.0,
            max_tenants: TENANTS,
        },
        demand: DemandSpec {
            base: 1.0,
            growth_per_epoch: 1.003,
            jitter: 0.3,
            kind: DemandKind::Diurnal {
                amplitude: 0.4,
                period: 7,
            },
        },
        workload: Some(WorkloadSpec {
            base: Workload {
                n_signals: 2,
                n_memvec: 8,
                obs_per_sec: 400.0,
                train_window: 32,
            },
            drift: containerstress::scenario::spec::WorkloadDrift {
                signals_growth: 1.001,
                memvecs_growth: 1.0015,
            },
        }),
        ..ScenarioSpec::default()
    }
}

/// The single-tenant degenerate scenario: constant-kind demand with zero
/// jitter is bit-identical to `GrowthTrace::exponential`.
fn degenerate_scenario(d0: f64, growth: f64, epochs: usize) -> ScenarioSpec {
    let json = format!(
        r#"{{
          "name": "degenerate", "seed": 1, "epochs": {epochs},
          "hours_per_epoch": 24,
          "arrivals": {{"initial": 1, "rate_per_epoch": 0, "max_tenants": 1}},
          "demand": {{"kind": "constant", "base": {d0},
                      "growth_per_epoch": {growth}, "jitter": 0}},
          "policies": [
            {{"kind": "prescoped", "headroom": 0.8}},
            {{"kind": "reactive"}}
          ]
        }}"#
    );
    ScenarioSpec::from_json(&Json::parse(&json).unwrap()).unwrap()
}

fn main() {
    containerstress::util::logger::init();
    let quick = figs::quick();

    // --- warm-up: measure the oracle grid once (cold cache) -------------
    let cache = SweepCache::in_memory();
    let sweep = oracle_sweep();
    let t0 = Instant::now();
    let result = run_sweep_cached(&sweep, Backend::Native, Some(&cache)).expect("oracle sweep");
    let warmup_s = t0.elapsed().as_secs_f64();
    let oracle = SurfaceOracle::from_sweep(&result).expect("fit oracle");
    println!(
        "fleet_scenarios: oracle grid measured in {warmup_s:.3}s ({} cells cached)",
        cache.len()
    );

    // --- assertion 1: trial-free oracle replay under 1 second ------------
    let scenario = fleet_scenario();
    let trials_before = Registry::global().counter("sweep.trials");
    let backend = Backend::Native;
    let backstop = Backstop {
        spec: &sweep,
        backend: &backend,
        cache: Some(&cache as &dyn CellStore),
    };
    let t0 = Instant::now();
    let outcome =
        run_scenario(&scenario, Some(&oracle), Some(&backstop)).expect("fleet replay");
    let replay_s = t0.elapsed().as_secs_f64();
    let fresh_trials = Registry::global().counter("sweep.trials") - trials_before;
    let stats = oracle.stats();
    println!(
        "replayed {} tenants × {} epochs × {} policies in {replay_s:.3}s \
         ({} surface + {} memo answers, {} fresh trials)",
        outcome.tenants,
        outcome.epochs,
        outcome.policies.len(),
        stats.surface_hits,
        stats.memo_hits,
        fresh_trials
    );
    println!("{}", outcome.render());
    assert_eq!(outcome.tenants, TENANTS, "fleet must reach full size");
    assert_eq!(
        fresh_trials, 0,
        "an in-domain replay must never execute a Monte Carlo trial"
    );
    assert_eq!(stats.fresh_trials, 0, "oracle backstop must stay idle");
    assert!(
        replay_s < 1.0,
        "1k-tenant × 365-epoch oracle replay took {replay_s:.3}s (budget 1s)"
    );

    // --- assertion 2: degenerate scenario == shapes::elastic, bitwise ----
    let mut mismatches = 0;
    for (d0, growth, epochs) in [(0.5, 1.04, 80), (0.3, 1.02, 200), (1.0, 1.01, 120)] {
        let spec = degenerate_scenario(d0, growth, epochs);
        let out = run_scenario(&spec, None, None).expect("degenerate replay");
        let trace = GrowthTrace::exponential(d0, growth, epochs, 24.0).unwrap();
        let (fixed, elastic) = compare(&trace, &ElasticPolicy::default());
        let pairs = [
            (&out.policies[0], &fixed),
            (&out.policies[1], &elastic),
        ];
        for (engine, reference) in pairs {
            if engine.total_usd.to_bits() != reference.total_usd.to_bits()
                || engine.violation_epochs != reference.violation_epochs
                || engine.migrations != reference.migrations
            {
                mismatches += 1;
                eprintln!(
                    "MISMATCH d0={d0} g={growth}: engine ({}, {}, {}) vs elastic \
                     ({}, {}, {})",
                    engine.total_usd,
                    engine.violation_epochs,
                    engine.migrations,
                    reference.total_usd,
                    reference.violation_epochs,
                    reference.migrations
                );
            }
        }
    }
    assert_eq!(
        mismatches, 0,
        "single-tenant scenarios must reproduce shapes::elastic bit-identically"
    );
    println!("degenerate single-tenant crossover: bit-identical to shapes::elastic");

    // --- emit artifacts ---------------------------------------------------
    let dir = std::path::Path::new("results");
    let points: Vec<PolicyPoint> = outcome.policy_points();
    let policies_json: Vec<Json> = points
        .iter()
        .map(|p| {
            Json::obj(vec![
                ("policy", Json::Str(p.label.clone())),
                ("total_usd", Json::Num(p.total_usd)),
                ("violation_epochs", Json::Num(p.violation_epochs as f64)),
                ("migrations", Json::Num(p.migrations as f64)),
            ])
        })
        .collect();
    let json = Json::obj(vec![
        ("bench", Json::Str("fleet_scenarios".into())),
        ("quick", Json::Bool(quick)),
        ("tenants", Json::Num(outcome.tenants as f64)),
        ("epochs", Json::Num(outcome.epochs as f64)),
        ("oracle_warmup_s", Json::Num(warmup_s)),
        ("replay_s", Json::Num(replay_s)),
        ("fresh_trials", Json::Num(fresh_trials as f64)),
        ("surface_hits", Json::Num(stats.surface_hits as f64)),
        ("memo_hits", Json::Num(stats.memo_hits as f64)),
        ("policies", Json::Arr(policies_json)),
        (
            "pareto",
            Json::arr_f64(&outcome.pareto.iter().map(|&i| i as f64).collect::<Vec<_>>()),
        ),
        ("degenerate_bit_identical", Json::Bool(true)),
    ]);
    report::write(dir, "BENCH_fleet.json", &json.to_pretty()).unwrap();
    let mut csv = String::from("policy,total_usd,violation_epochs,migrations\n");
    for p in &points {
        csv.push_str(&format!(
            "{},{},{},{}\n",
            p.label, p.total_usd, p.violation_epochs, p.migrations
        ));
    }
    report::write(dir, "fleet_scenarios.csv", &csv).unwrap();
    println!("fleet_scenarios done → results/BENCH_fleet.json, results/fleet_scenarios.csv");
}
