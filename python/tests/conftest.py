import jax

# Enable f64 before anything traces: the training graph upcasts its
# Newton–Schulz inverse to f64 (model.mset2_train), and the oracles compare
# against f64 numpy.
jax.config.update("jax_enable_x64", True)
