//! Minimal JSON model, parser and writer.
//!
//! `serde`/`serde_json` are not available in the offline build environment,
//! so this module provides the small JSON surface ContainerStress needs:
//! the artifact manifest written by `python/compile/aot.py`, config files,
//! and metrics/report export.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects preserve key order via `BTreeMap` (sorted), which
/// is sufficient for manifests and keeps output deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always an f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys).
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {offset}: {msg}")]
pub struct JsonError {
    /// Byte offset of the error in the input.
    pub offset: usize,
    /// Human-readable description.
    pub msg: String,
}

impl Json {
    // ---- constructors -------------------------------------------------

    /// Object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Array of numbers.
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Array of strings.
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::Str(s.to_string())).collect())
    }

    // ---- accessors -----------------------------------------------------

    /// Object member lookup (`None` for non-objects/missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with the key name — manifest parsing.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key: {key}"))
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Numeric value as a non-negative integer (rejects fractions).
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().and_then(|x| {
            if x >= 0.0 && x.fract() == 0.0 {
                Some(x as usize)
            } else {
                None
            }
        })
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a boolean.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Object members, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    // ---- parsing -------------------------------------------------------

    /// Parse a complete JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // ---- writing ---------------------------------------------------------

    /// Compact single-line encoding.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty-printed encoding with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_str(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_nan() || x.is_infinite() {
        // JSON has no NaN/Inf; encode as null like most tolerant writers.
        out.push_str("null");
    } else if x.fract() == 0.0 && x.abs() < 9e15 {
        out.push_str(&format!("{}", x as i64));
    } else {
        out.push_str(&format!("{x}"));
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // NOTE: surrogate pairs not needed for our manifests;
                            // replace lone surrogates with U+FFFD.
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(
            Json::parse(r#""a\nbA""#).unwrap(),
            Json::Str("a\nbA".into())
        );
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": false}"#).unwrap();
        assert_eq!(v.get("d"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("c"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"name":"mset2_train","shapes":[8,16,32],"pi":3.25,"ok":true,"none":null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.to_pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse(r#""héllo⚡""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo⚡"));
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integers_encode_without_fraction() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn as_usize_guards() {
        assert_eq!(Json::Num(8.0).as_usize(), Some(8));
        assert_eq!(Json::Num(-1.0).as_usize(), None);
        assert_eq!(Json::Num(1.5).as_usize(), None);
    }
}
