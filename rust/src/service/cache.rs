//! Cell-level **sweep cache** — the measurement store behind the service.
//!
//! Every Monte Carlo sweep decomposes into independent grid cells, and a
//! cell's measured trial *sequence* is fully determined by the tuple
//! `(cell, model, seed, backend)` — trial seeds are derived from the cell
//! content and the trial index, see [`crate::coordinator::sweep`]. The
//! cache is therefore content-addressed on that tuple, with the entry
//! holding however many trials have been measured so far: an exhaustive
//! sweep reuses a longer entry as a prefix, and the adaptive planner
//! counts any stored trials toward its convergence target and tops the
//! entry up in place. Identical cells across scoping requests are never
//! re-measured, turning repeated customer scoping into a cheap
//! surface-fit + recommend over stored measurements — the "build oracles,
//! don't re-run the experiment" economics the service exists for.
//!
//! Storage is an in-memory map with an optional JSON spill directory: each
//! entry is one small self-describing file named by the FNV-1a hash of its
//! canonical key, so a warm cache survives service restarts. Entries are
//! wall-clock timings of *this* testbed — do not share a spill directory
//! between machines of different hardware, and wipe it after a hardware
//! change; the recommender's calibration assumes the measuring host.

use crate::coordinator::sweep::{CellKey, CellStore, SweepSpec};
use crate::metrics::Registry;
use crate::util::failpoint;
use crate::util::fnv1a;
use crate::util::json::Json;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

pub use crate::coordinator::sweep::CellCosts;

/// Full identity of one cached cell measurement. Deliberately excludes any
/// trial count: the entry stores the measured prefix of the cell's
/// deterministic trial sequence, whatever its current length.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct CacheKey {
    /// Grid coordinate.
    pub cell: CellKey,
    /// Model name (`mset2` | `aakr` | …).
    pub model: String,
    /// Sweep root seed (trial seeds derive from it).
    pub seed: u64,
    /// Backend tag (`device` | `native`).
    pub backend: String,
}

impl CacheKey {
    /// Key for a cell measured under `spec` on the named backend.
    pub fn new(cell: CellKey, spec: &SweepSpec, backend: &str) -> CacheKey {
        CacheKey {
            cell,
            model: spec.model.clone(),
            seed: spec.seed,
            backend: backend.to_string(),
        }
    }

    /// Canonical string form (the content address). The `v2` prefix is the
    /// entry-schema version: bump it to invalidate old spill dirs
    /// (`v1` keyed on the trial count; `v2` entries are length-agnostic).
    pub fn canonical(&self) -> String {
        format!(
            "v2|model={}|backend={}|seed={}|n={}|m={}|obs={}",
            self.model, self.backend, self.seed, self.cell.n, self.cell.m, self.cell.obs
        )
    }

    /// Spill-file stem: hex FNV-1a of the canonical form.
    pub fn file_stem(&self) -> String {
        stem_of(&self.canonical())
    }
}

/// Spill-file stem for a canonical key (single definition — eviction and
/// insertion must always derive the same file name).
fn stem_of(canonical: &str) -> String {
    format!("{:016x}", fnv1a(canonical.as_bytes()))
}

/// Upper bound on cached cells. Keys are client-controlled through the
/// service (`seed`, axes, …), so the store must not grow without limit: at
/// the cap an arbitrary entry (and its spill file) is evicted per insert.
pub const MAX_CACHED_CELLS: usize = 65_536;

/// How many times one spill write is attempted before the cache gives
/// up on the disk and degrades to memory-only mode.
const SPILL_WRITE_ATTEMPTS: u64 = 2;

/// Content-addressed store of cell measurements (thread-safe).
pub struct SweepCache {
    dir: Option<PathBuf>,
    map: Mutex<HashMap<String, CellCosts>>,
    hits: AtomicU64,
    misses: AtomicU64,
    /// Set once a spill write exhausts its retries: the cache keeps
    /// serving from memory but stops touching the disk, and `/healthz`
    /// reports `degraded` with [`SweepCache::degrade_reason`].
    degraded: AtomicBool,
    degrade_reason: Mutex<Option<String>>,
    spill_errors: AtomicU64,
}

impl SweepCache {
    /// Volatile cache (no disk spill) — tests and `--cache-dir none`.
    pub fn in_memory() -> SweepCache {
        SweepCache {
            dir: None,
            map: Mutex::new(HashMap::new()),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
            degrade_reason: Mutex::new(None),
            spill_errors: AtomicU64::new(0),
        }
    }

    /// Open (or create) a disk-backed cache, loading every valid spilled
    /// entry up front. Unreadable entries are skipped with a warning, not
    /// fatal — the cache must never take the service down.
    pub fn open(dir: impl AsRef<Path>) -> anyhow::Result<SweepCache> {
        let dir = dir.as_ref().to_path_buf();
        std::fs::create_dir_all(&dir)
            .map_err(|e| anyhow::anyhow!("cache dir {}: {e}", dir.display()))?;
        let mut map = HashMap::new();
        for entry in std::fs::read_dir(&dir)? {
            if map.len() >= MAX_CACHED_CELLS {
                log::warn!("sweep cache: load cap {MAX_CACHED_CELLS} reached; rest ignored");
                break;
            }
            let path = entry?.path();
            if path.extension().and_then(|e| e.to_str()) != Some("json") {
                continue;
            }
            let tag = fnv1a(
                path.file_name()
                    .and_then(|s| s.to_str())
                    .unwrap_or_default()
                    .as_bytes(),
            );
            match failpoint::hit_no_panic("cellstore.spill.read", tag)
                .ok()
                .and_then(|_| std::fs::read_to_string(&path).ok())
                .and_then(|text| Json::parse(&text).ok())
                .and_then(|j| parse_entry(&j))
            {
                Some((key, costs)) => {
                    // A file must live under its own canonical stem. Files
                    // from older schema versions (v1 stems) parse fine but
                    // are skipped: they would collide with the v2 address
                    // while put()/eviction only ever touch the v2-stem
                    // file, letting a stale entry shadow or resurrect a
                    // newer one across restarts.
                    let stem = path.file_stem().and_then(|s| s.to_str());
                    if stem == Some(key.file_stem().as_str()) {
                        map.insert(key.canonical(), costs);
                    } else {
                        log::warn!(
                            "sweep cache: skipping {} (foreign schema version)",
                            path.display()
                        );
                    }
                }
                None => {
                    Registry::global().inc("cache.spill.read_skipped");
                    log::warn!("sweep cache: skipping unreadable {}", path.display());
                }
            }
        }
        log::info!("sweep cache: {} entries loaded from {}", map.len(), dir.display());
        Ok(SweepCache {
            dir: Some(dir),
            map: Mutex::new(map),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            degraded: AtomicBool::new(false),
            degrade_reason: Mutex::new(None),
            spill_errors: AtomicU64::new(0),
        })
    }

    /// Look up a cell; counts a hit or miss (locally and in the global
    /// metrics registry). A hit means the stored trial prefix is reused —
    /// possibly topped up with further trials when the request wants more
    /// than the entry holds, but never discarded.
    pub fn get(&self, key: &CacheKey) -> Option<CellCosts> {
        let found = self.map.lock().unwrap().get(&key.canonical()).cloned();
        match &found {
            Some(_) => {
                self.hits.fetch_add(1, Ordering::Relaxed);
                Registry::global().inc("sweep.cache.hits");
            }
            None => {
                self.misses.fetch_add(1, Ordering::Relaxed);
                Registry::global().inc("sweep.cache.misses");
            }
        }
        found
    }

    /// Insert a measurement, spilling it to disk when a directory is
    /// configured. Spill failures are retried once, then the cache
    /// **degrades to memory-only mode**: the entry stays served from
    /// memory, later inserts skip the disk, and the degradation is
    /// surfaced through [`SweepCache::degrade_reason`] (→ `/healthz`)
    /// and the `cache.spill.errors` counter — an unwritable disk must
    /// never fail a job or take the service down. At
    /// [`MAX_CACHED_CELLS`] an arbitrary entry is evicted (memory +
    /// spill file) to keep the store bounded.
    pub fn put(&self, key: CacheKey, costs: CellCosts) {
        let canon = key.canonical();
        {
            let mut map = self.map.lock().unwrap();
            if map.len() >= MAX_CACHED_CELLS && !map.contains_key(&canon) {
                if let Some(victim) = map.keys().next().cloned() {
                    map.remove(&victim);
                    if let Some(dir) = &self.dir {
                        let _ =
                            std::fs::remove_file(dir.join(format!("{}.json", stem_of(&victim))));
                    }
                    Registry::global().inc("sweep.cache.evictions");
                }
            }
            map.insert(canon, costs.clone());
        }
        if let Some(dir) = &self.dir {
            if self.degraded.load(Ordering::Relaxed) {
                return;
            }
            // Spill files carry the seed as a JSON f64; a seed above 2^53
            // would reload rounded, silently never matching its key again.
            // Keep such entries memory-only (CLI-only case — the service
            // path rejects non-round-trippable seeds at parse time).
            if key.seed as f64 as u64 != key.seed {
                log::debug!("sweep cache: seed {} not f64-exact; entry not spilled", key.seed);
                return;
            }
            let path = dir.join(format!("{}.json", key.file_stem()));
            let body = entry_json(&key, &costs).to_pretty();
            let tag = fnv1a(key.file_stem().as_bytes());
            let mut last_err = None;
            for attempt in 0..SPILL_WRITE_ATTEMPTS {
                let r = failpoint::hit_no_panic("cellstore.spill.write", tag.wrapping_add(attempt))
                    .and_then(|_| std::fs::write(&path, &body).map_err(anyhow::Error::from));
                match r {
                    Ok(()) => return,
                    Err(e) => {
                        self.spill_errors.fetch_add(1, Ordering::Relaxed);
                        Registry::global().inc("cache.spill.errors");
                        last_err = Some(e);
                    }
                }
            }
            let reason = format!(
                "sweep cache degraded to memory-only: spill to {} failed after \
                 {SPILL_WRITE_ATTEMPTS} attempts: {:#}",
                path.display(),
                last_err.expect("retry loop ran")
            );
            log::error!("{reason}");
            *self.degrade_reason.lock().unwrap() = Some(reason);
            self.degraded.store(true, Ordering::SeqCst);
        }
    }

    /// Number of cached cells.
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    /// Whether the cache holds no cells.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes of the cached measurements (per-trial
    /// f64 timings; keys and map overhead excluded). Feeds the
    /// `cache.bytes` gauge at metrics-scrape time.
    pub fn bytes(&self) -> usize {
        let map = self.map.lock().unwrap();
        map.values()
            .map(|c| (c.train_s.len() + c.surveil_s.len()) * std::mem::size_of::<f64>())
            .sum()
    }

    /// Lookup hits since this instance was created.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Lookup misses since this instance was created.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// True once spill writes have been abandoned (memory-only mode).
    pub fn is_degraded(&self) -> bool {
        self.degraded.load(Ordering::Relaxed)
    }

    /// Human-readable reason the cache degraded, when it has.
    pub fn degrade_reason(&self) -> Option<String> {
        self.degrade_reason.lock().unwrap().clone()
    }

    /// Spill write errors observed (including retried ones).
    pub fn spill_errors(&self) -> u64 {
        self.spill_errors.load(Ordering::Relaxed)
    }
}

/// The coordinator-facing store interface ([`crate::coordinator::sweep`]
/// consults this through the trait, never through this module directly).
impl CellStore for SweepCache {
    fn fetch(&self, cell: CellKey, spec: &SweepSpec, backend: &str) -> Option<CellCosts> {
        self.get(&CacheKey::new(cell, spec, backend))
    }

    fn store(&self, cell: CellKey, spec: &SweepSpec, backend: &str, costs: CellCosts) {
        self.put(CacheKey::new(cell, spec, backend), costs);
    }
}

fn entry_json(key: &CacheKey, costs: &CellCosts) -> Json {
    Json::obj(vec![
        ("backend", Json::Str(key.backend.clone())),
        ("model", Json::Str(key.model.clone())),
        ("seed", Json::Num(key.seed as f64)),
        ("n", Json::Num(key.cell.n as f64)),
        ("m", Json::Num(key.cell.m as f64)),
        ("obs", Json::Num(key.cell.obs as f64)),
        ("train_s", Json::arr_f64(&costs.train_s)),
        ("surveil_s", Json::arr_f64(&costs.surveil_s)),
    ])
}

fn f64_list(j: &Json) -> Option<Vec<f64>> {
    let arr = j.as_arr()?;
    let v: Vec<f64> = arr.iter().filter_map(Json::as_f64).collect();
    if v.len() == arr.len() {
        Some(v)
    } else {
        None
    }
}

fn parse_entry(j: &Json) -> Option<(CacheKey, CellCosts)> {
    let key = CacheKey {
        cell: CellKey {
            n: j.get("n")?.as_usize()?,
            m: j.get("m")?.as_usize()?,
            obs: j.get("obs")?.as_usize()?,
        },
        model: j.get("model")?.as_str()?.to_string(),
        seed: j.get("seed")?.as_f64()? as u64,
        backend: j.get("backend")?.as_str()?.to_string(),
    };
    let costs = CellCosts {
        train_s: f64_list(j.get("train_s")?)?,
        surveil_s: f64_list(j.get("surveil_s")?)?,
    };
    // A valid entry carries the same number ≥ 1 of measurements for both
    // phases (they share the trial schedule); anything else is a corrupt
    // or foreign file. (Old `v1` files also parse, but `open()` rejects
    // them by their file stem so they cannot shadow `v2` entries.)
    if costs.train_s.is_empty() || costs.train_s.len() != costs.surveil_s.len() {
        return None;
    }
    Some((key, costs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(n: usize, m: usize, obs: usize) -> CacheKey {
        CacheKey {
            cell: CellKey { n, m, obs },
            model: "mset2".into(),
            seed: 7,
            backend: "native".into(),
        }
    }

    fn costs() -> CellCosts {
        CellCosts {
            train_s: vec![0.5, 0.625],
            surveil_s: vec![0.25, 0.125],
        }
    }

    #[test]
    fn memory_roundtrip_and_accounting() {
        let c = SweepCache::in_memory();
        assert!(c.get(&key(4, 8, 32)).is_none());
        c.put(key(4, 8, 32), costs());
        assert_eq!(c.get(&key(4, 8, 32)), Some(costs()));
        assert_eq!((c.hits(), c.misses(), c.len()), (1, 1, 1));
        // 4 stored f64 timings (2 train + 2 surveil)
        assert_eq!(c.bytes(), 4 * std::mem::size_of::<f64>());
        // any key component change is a different address
        assert!(c.get(&key(4, 8, 64)).is_none());
        let other = CacheKey {
            seed: 8,
            ..key(4, 8, 32)
        };
        assert!(c.get(&other).is_none());
        assert_eq!(c.misses(), 3);
    }

    #[test]
    fn disk_spill_survives_reopen() {
        let dir = std::env::temp_dir().join(format!(
            "cs_cache_test_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let c = SweepCache::open(&dir).unwrap();
            c.put(key(4, 8, 32), costs());
            c.put(key(8, 16, 64), costs());
        }
        let c2 = SweepCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        assert_eq!(c2.get(&key(4, 8, 32)), Some(costs()));
        // costs round-trip exactly through the JSON writer
        assert_eq!(c2.get(&key(8, 16, 64)).unwrap().surveil_s, vec![0.25, 0.125]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_spill_entries_are_skipped() {
        let dir = std::env::temp_dir().join(format!(
            "cs_cache_corrupt_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("bad.json"), "{not json").unwrap();
        std::fs::write(dir.join("wrong.json"), r#"{"n": 4}"#).unwrap();
        // phase mismatch: 2 train timings but 1 surveillance timing
        std::fs::write(
            dir.join("mismatch.json"),
            r#"{"backend":"native","model":"mset2","seed":1,"n":4,"m":8,"obs":16,"train_s":[0.1,0.2],"surveil_s":[0.1]}"#,
        )
        .unwrap();
        // empty entry: no measurements at all
        std::fs::write(
            dir.join("empty.json"),
            r#"{"backend":"native","model":"mset2","seed":1,"n":4,"m":8,"obs":16,"train_s":[],"surveil_s":[]}"#,
        )
        .unwrap();
        // well-formed content under a foreign (e.g. v1-era) file stem:
        // must be rejected so it can never shadow the v2-stem entry
        std::fs::write(
            dir.join("00deadbeef00cafe.json"),
            r#"{"backend":"native","model":"mset2","seed":1,"n":4,"m":8,"obs":16,"train_s":[0.1],"surveil_s":[0.1]}"#,
        )
        .unwrap();
        let c = SweepCache::open(&dir).unwrap();
        assert!(c.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_write_failure_degrades_to_memory_only() {
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        let dir = std::env::temp_dir().join(format!(
            "cs_cache_degrade_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let c = SweepCache::open(&dir).unwrap();
        // Simulated disk-full: every spill write fails, including the retry.
        failpoint::arm_from_str("cellstore.spill.write:1:error:5").unwrap();
        c.put(key(4, 8, 32), costs());
        assert!(c.is_degraded(), "exhausted retries must degrade the cache");
        assert!(c.degrade_reason().unwrap().contains("memory-only"));
        assert_eq!(c.spill_errors(), SPILL_WRITE_ATTEMPTS);
        // Entries keep being served from memory; later puts skip the disk
        // without accumulating further errors.
        assert_eq!(c.get(&key(4, 8, 32)), Some(costs()));
        c.put(key(8, 16, 64), costs());
        assert_eq!(c.spill_errors(), SPILL_WRITE_ATTEMPTS);
        assert_eq!(c.len(), 2);
        failpoint::disarm_all();
        // Nothing reached the disk, so a reopen starts cold — but clean.
        let c2 = SweepCache::open(&dir).unwrap();
        assert!(c2.is_empty());
        assert!(!c2.is_degraded());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn spill_read_faults_skip_entries_without_crashing() {
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        let dir = std::env::temp_dir().join(format!(
            "cs_cache_readfault_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let c = SweepCache::open(&dir).unwrap();
            c.put(key(4, 8, 32), costs());
            c.put(key(8, 16, 64), costs());
        }
        // Every read faults: open() must come up empty, not crash.
        failpoint::arm_from_str("cellstore.spill.read:1:error:5").unwrap();
        let c = SweepCache::open(&dir).unwrap();
        assert!(c.is_empty());
        failpoint::disarm_all();
        // Fault cleared: both entries load again — nothing was corrupted.
        let c2 = SweepCache::open(&dir).unwrap();
        assert_eq!(c2.len(), 2);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn torn_spill_file_is_skipped_and_reexecutable() {
        let _g = failpoint::test_guard();
        failpoint::disarm_all();
        let dir = std::env::temp_dir().join(format!(
            "cs_cache_torn_{}_{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        {
            let c = SweepCache::open(&dir).unwrap();
            c.put(key(4, 8, 32), costs());
        }
        // Tear the spill file mid-write (half its bytes survive a crash).
        let path = dir.join(format!("{}.json", key(4, 8, 32).file_stem()));
        let full = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &full[..full.len() / 2]).unwrap();
        let c = SweepCache::open(&dir).unwrap();
        assert!(c.is_empty(), "torn entry must be skipped, not crash the load");
        // The cell is simply a miss now — it will be re-executed and the
        // torn file overwritten by the fresh spill.
        assert!(c.get(&key(4, 8, 32)).is_none());
        c.put(key(4, 8, 32), costs());
        let c2 = SweepCache::open(&dir).unwrap();
        assert_eq!(c2.get(&key(4, 8, 32)), Some(costs()));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn canonical_keys_are_distinct() {
        let a = key(4, 8, 32);
        let mut seen = std::collections::HashSet::new();
        for k in [
            a.clone(),
            CacheKey {
                backend: "device".into(),
                ..a.clone()
            },
            CacheKey {
                model: "aakr".into(),
                ..a.clone()
            },
            CacheKey { seed: 8, ..a.clone() },
            key(4, 8, 64),
        ] {
            assert!(seen.insert(k.canonical()), "collision: {}", k.canonical());
        }
    }
}
