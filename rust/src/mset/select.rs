//! Memory-vector (training-vector) selection — classic MSET two-pass
//! procedure:
//!
//! 1. **Extrema coverage**: every observation that carries the minimum or
//!    maximum of any signal enters the memory matrix, so the model spans the
//!    observed operating envelope.
//! 2. **Norm-spaced fill**: the remaining slots are filled by ordering the
//!    unchosen observations by vector norm and taking evenly spaced ranks,
//!    giving uniform coverage of the state space in between.
//!
//! Selection runs on *scaled* data, once per training set; it is data
//! preparation, not part of the streamed hot path, so it lives in L3
//! rather than in the AOT graphs.

use crate::linalg::Mat;

/// Select `m` row indices of `xs` (scaled training data, rows=observations)
/// to serve as memory vectors. Deterministic; returns indices sorted by the
/// order of selection (extrema first).
pub fn select_memory(xs: &Mat, m: usize) -> Vec<usize> {
    let t = xs.rows;
    let n = xs.cols;
    assert!(m <= t, "cannot select {m} from {t} observations");

    let mut chosen = vec![false; t];
    let mut out = Vec::with_capacity(m);

    // Pass 1: extrema of each signal.
    for j in 0..n {
        let mut lo = 0usize;
        let mut hi = 0usize;
        for i in 1..t {
            if xs[(i, j)] < xs[(lo, j)] {
                lo = i;
            }
            if xs[(i, j)] > xs[(hi, j)] {
                hi = i;
            }
        }
        for idx in [lo, hi] {
            if !chosen[idx] && out.len() < m {
                chosen[idx] = true;
                out.push(idx);
            }
        }
    }

    // Pass 2: norm-spaced fill over the remainder.
    if out.len() < m {
        let mut rest: Vec<(f64, usize)> = (0..t)
            .filter(|&i| !chosen[i])
            .map(|i| {
                let norm2: f64 = xs.row(i).iter().map(|v| v * v).sum();
                (norm2, i)
            })
            .collect();
        rest.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        let need = m - out.len();
        // evenly spaced ranks across the sorted remainder
        for k in 0..need {
            let pos = if need == 1 {
                0
            } else {
                k * (rest.len() - 1) / (need - 1)
            };
            let idx = rest[pos].1;
            if !chosen[idx] {
                chosen[idx] = true;
                out.push(idx);
            }
        }
        // rank collisions are possible when need ~ rest.len(); top up linearly
        let mut it = rest.iter();
        while out.len() < m {
            let &(_, idx) = it.next().expect("enough observations checked above");
            if !chosen[idx] {
                chosen[idx] = true;
                out.push(idx);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(rows, cols);
        rng.fill_gauss(&mut m.data);
        m
    }

    #[test]
    fn selects_exactly_m_distinct() {
        let xs = random_mat(500, 6, 1);
        for m in [12, 64, 200, 500] {
            let idx = select_memory(&xs, m);
            assert_eq!(idx.len(), m);
            let mut s = idx.clone();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), m, "duplicates for m={m}");
            assert!(idx.iter().all(|&i| i < 500));
        }
    }

    #[test]
    fn extrema_always_included() {
        let xs = random_mat(300, 4, 2);
        let idx = select_memory(&xs, 32);
        for j in 0..4 {
            let col: Vec<f64> = xs.col(j).collect();
            let lo = (0..300)
                .min_by(|&a, &b| col[a].partial_cmp(&col[b]).unwrap())
                .unwrap();
            let hi = (0..300)
                .max_by(|&a, &b| col[a].partial_cmp(&col[b]).unwrap())
                .unwrap();
            assert!(idx.contains(&lo), "min of signal {j} not selected");
            assert!(idx.contains(&hi), "max of signal {j} not selected");
        }
    }

    #[test]
    fn deterministic() {
        let xs = random_mat(200, 3, 3);
        assert_eq!(select_memory(&xs, 40), select_memory(&xs, 40));
    }

    #[test]
    fn m_equals_t_selects_all() {
        let xs = random_mat(50, 2, 4);
        let mut idx = select_memory(&xs, 50);
        idx.sort_unstable();
        assert_eq!(idx, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn norm_coverage_spread() {
        // Selected vectors should span the norm range, not cluster.
        let xs = random_mat(1000, 5, 5);
        let idx = select_memory(&xs, 64);
        let norms: Vec<f64> = idx
            .iter()
            .map(|&i| xs.row(i).iter().map(|v| v * v).sum::<f64>().sqrt())
            .collect();
        let all_norms: Vec<f64> = (0..1000)
            .map(|i| xs.row(i).iter().map(|v| v * v).sum::<f64>().sqrt())
            .collect();
        let max_all = all_norms.iter().cloned().fold(0.0, f64::max);
        let max_sel = norms.iter().cloned().fold(0.0, f64::max);
        let min_all = all_norms.iter().cloned().fold(f64::INFINITY, f64::min);
        let min_sel = norms.iter().cloned().fold(f64::INFINITY, f64::min);
        // top/bottom 10% of the norm range must be represented
        assert!(max_sel > max_all - 0.1 * (max_all - min_all));
        assert!(min_sel < min_all + 0.2 * (max_all - min_all));
    }
}
