//! **Fig. 4 (a)–(d)**: 3-D training compute-cost contours vs (number of
//! memory vectors × number of training observations), one panel per signal
//! count. Paper panels use 10/20/30/40 signals; the scaled grid uses the
//! artifact bucket axes (DESIGN.md §5). Expected shape: cost dominated by
//! `n_memvec` (and signals across panels), nearly flat in `n_obs` — the
//! paper's §III.A training conclusion.
//!
//! Output: `results/fig4_training_cost/` (CSV + gnuplot + ASCII per panel)
//! and a fitted sensitivity table on stdout.

use containerstress::bench::figs;
use containerstress::report;
use containerstress::surface::{ResponseSurface, Sample, SurfaceGrid};
use std::path::Path;

fn main() {
    containerstress::util::logger::init();
    let server = figs::device_or_exit();
    let handle = server.handle();
    let (signals, memvecs) = figs::available_axes(&handle);
    let trials = if figs::quick() { 1 } else { 3 };
    let obs_axis: Vec<usize> = if figs::quick() {
        vec![256, 1024]
    } else {
        vec![256, 1024, 4096]
    };
    let out = Path::new("results/fig4_training_cost");
    println!(
        "fig4: panels(signals)={signals:?}, memvecs={memvecs:?}, train-obs={obs_axis:?}, {trials} trials"
    );

    let mut samples = Vec::new();
    for (pi, &n) in signals.iter().enumerate() {
        let mut grid = SurfaceGrid::new(
            "n_memvec",
            "n_train_obs",
            memvecs.iter().map(|&v| v as f64).collect(),
            obs_axis.iter().map(|&v| v as f64).collect(),
        );
        for (r, &m) in memvecs.iter().enumerate() {
            if m < 2 * n {
                continue; // training-constraint gap (paper Fig. 6 note)
            }
            for (c, &obs) in obs_axis.iter().enumerate() {
                let ts = figs::measure_train(&handle, n, m, obs, trials);
                let med = figs::median(&ts);
                grid.set(r, c, med);
                samples.push(Sample {
                    n_signals: n,
                    n_memvec: m,
                    n_obs: obs,
                    cost: med,
                });
            }
        }
        let panel = (b'a' + pi as u8) as char;
        let ascii = report::emit_figure(
            out,
            &format!("fig4{panel}_n{n}"),
            &format!("Fig4({panel}): training cost, {n} signals"),
            &grid,
            "train_cost_s",
            false,
        )
        .expect("emit");
        println!("{ascii}");
    }

    let surf = ResponseSurface::fit(&samples).expect("fit");
    println!(
        "training-cost surface: r²={:.3}, exponents (n, m, obs) = {:?}",
        surf.r2,
        surf.exponents().map(|e| (e * 1000.0).round() / 1000.0)
    );
    let rank = surf.ranking();
    println!("dominant parameters: {} > {} > {}", rank[0].0, rank[1].0, rank[2].0);
    // Paper §III.A: training cost "depends very sensitively on the number
    // of memory vectors" and is insensitive to the observation count. (At
    // this grid's signal range the n·m² similarity term is dwarfed by the
    // m³ inverse, so the n exponent is also near zero — n and obs then
    // rank by noise; we assert the physical claims, not the noise.)
    assert_eq!(rank[0].0, "n_memvec", "memvecs must dominate training");
    let e = surf.exponents();
    assert!(
        e[2].abs() < 0.3,
        "training must be near-flat in n_obs: exponents {e:?}"
    );
    println!("fig4 done → {}", out.display());
}
