//! **BENCH-kernel**: reference vs blocked vs SIMD kernel tiers on the
//! native MSET trial hot path (§II.D).
//!
//! Four gates, enforced with asserts so CI catches regressions:
//!
//! 1. **Accuracy** — the blocked `sim_cross`/`sim_matrix` kernels agree
//!    with the per-pair reference implementations to ≤ 1e-10 at every
//!    grid size (the scalar tier is designed to be far closer; see
//!    `linalg::kernel`'s bit-stability contract), and so does the SIMD
//!    tier when one exists (tolerance mode).
//! 2. **Kernel speedup** — blocked `sim_cross` + Gram (`sim_matrix`)
//!    combined are ≥ 3× the reference formulations at n = 1024.
//! 3. **SIMD speedup** — when a vector tier is detected (AVX2+FMA or
//!    NEON), SIMD `sim_cross` + Gram combined are ≥ 2× the scalar
//!    blocked tier at n = 1024. Without one the floor is skipped with a
//!    logged notice; `CONTAINERSTRESS_KERNEL=simd` + no vector tier
//!    skips the whole bench the same way (for the CI SIMD-forced step).
//! 4. **End-to-end** — a full native MSET2 trial (synthesize → scale →
//!    select → train → surveil) on the production kernel stack is
//!    ≥ 1.5× a twin trial built from the naive reference kernels.
//!
//! A final calibration pass measures effective CPU GFLOP/s per backend
//! from full `MsetPlugin` fit/estimate cells; the `"calibration"` rows
//! it emits are what `accel::measured_cpu_ref()` feeds into `recommend`.
//!
//! Output: `results/BENCH_kernel.json` + `results/kernel_hotpath.csv`
//! (the README perf table is sourced from the JSON). `CS_BENCH_QUICK=1`
//! shortens the measuring windows but keeps every asserted point.

use containerstress::accel;
use containerstress::bench::{black_box, figs, table, write_csv, Bencher, Measurement};
use containerstress::linalg::{eigh, kernel, simd, Mat};
use containerstress::models::{MsetPlugin, PrognosticModel};
use containerstress::mset::{
    select_memory, sim_cross_ref, sim_matrix_ref, Scaler, RIDGE_REL,
};
use containerstress::report;
use containerstress::tpss::{synthesize, TpssConfig};
use containerstress::util::json::Json;
use containerstress::util::rng::Rng;

fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    let mut m = Mat::zeros(rows, cols);
    rng.fill_gauss(&mut m.data);
    m
}

/// The pre-blocked `reg_pinv`: eigendecomposition plus the naive
/// `V·diag(1/(w+λ))·Vᵀ` triple-loop reconstruction.
fn reg_pinv_ref(a: &Mat, lambda: f64) -> Mat {
    let (w, v) = eigh(a);
    let n = a.rows;
    let floor = 1e-12 * w.iter().fold(0.0f64, |m, &x| m.max(x.abs())).max(1e-12);
    let mut out = Mat::zeros(n, n);
    for k in 0..n {
        let d = 1.0 / (w[k] + lambda).max(floor);
        for i in 0..n {
            let vik = v[(i, k)] * d;
            if vik == 0.0 {
                continue;
            }
            for j in 0..n {
                out[(i, j)] += vik * v[(j, k)];
            }
        }
    }
    out
}

/// One native MSET2 trial on the naive reference kernels: the exact
/// pre-blocked pipeline, sharing synthesis/scaling/selection with the
/// production twin so only the kernel stack differs.
fn reference_trial(n: usize, m: usize, obs: usize, seed: u64) -> Mat {
    let train_ds = synthesize(&TpssConfig::sized(n, obs.max(m)), seed);
    let probe_ds = synthesize(&TpssConfig::sized(n, obs), seed ^ 0x5EED);
    let scaler = Scaler::fit(&train_ds.data);
    let xs = scaler.transform(&train_ds.data);
    let idx = select_memory(&xs, m);
    let mut d = Mat::zeros(m, n);
    for (r, &i) in idx.iter().enumerate() {
        d.row_mut(r).copy_from_slice(xs.row(i));
    }
    // train: S = sim(D, D), G = (S + λI)⁻¹
    let mut s = sim_matrix_ref(&d);
    let trace: f64 = (0..m).map(|i| s[(i, i)]).sum();
    let lambda = RIDGE_REL * trace / m as f64;
    for i in 0..m {
        s[(i, i)] += lambda;
    }
    let g = reg_pinv_ref(&s, 0.0);
    // surveil: X̂ = (G·K)ᵀ·D over the naive kernels
    let probe = scaler.transform(&probe_ds.data);
    let k = sim_cross_ref(&d, &probe);
    let w = kernel::reference::matmul(&g, &k);
    kernel::reference::matmul(&w.transpose(), &d)
}

/// The production twin: the same trial through `models::MsetPlugin`
/// (blocked kernels + workspace arena), returning X̂ for the accuracy
/// cross-check.
fn production_trial(n: usize, m: usize, obs: usize, seed: u64) -> Mat {
    let train_ds = synthesize(&TpssConfig::sized(n, obs.max(m)), seed);
    let probe_ds = synthesize(&TpssConfig::sized(n, obs), seed ^ 0x5EED);
    let mut plugin = MsetPlugin::default();
    plugin.fit(&train_ds.data, m).expect("fit");
    plugin.estimate(&probe_ds.data).xhat
}

fn main() {
    containerstress::util::logger::init();
    let quick = figs::quick();
    let b = if quick {
        Bencher::quick()
    } else {
        Bencher::default()
    };

    const MAX_KERNEL_DIFF: f64 = 1e-10;
    const MIN_KERNEL_SPEEDUP: f64 = 3.0; // sim_cross + Gram at n = 1024
    const MIN_SIMD_SPEEDUP: f64 = 2.0; // SIMD vs scalar blocked at n = 1024
    const MIN_E2E_SPEEDUP: f64 = 1.5; // full native trial

    // CI's SIMD-forced variant sets CONTAINERSTRESS_KERNEL=simd; on a
    // host without a vector tier that run has nothing to measure, so it
    // skips cleanly instead of degrading to a duplicate scalar run.
    let simd_tier = simd::detect();
    let forced_simd = std::env::var(simd::ENV_KNOB)
        .map(|v| v.trim().eq_ignore_ascii_case("simd"))
        .unwrap_or(false);
    if forced_simd && simd_tier.is_none() {
        println!(
            "kernel_hotpath: {}=simd requested but this host has no SIMD tier \
             (need AVX2+FMA on x86_64 or NEON on aarch64); skipping bench",
            simd::ENV_KNOB
        );
        return;
    }
    // Pin the scalar tier for the baseline sections regardless of the env
    // knob; the SIMD sections below switch tiers explicitly.
    simd::install(simd::BackendRequest::Scalar, "bench").expect("scalar install cannot fail");

    let sizes: &[usize] = if quick {
        &[64, 256, 1024]
    } else {
        &[64, 128, 256, 512, 1024]
    };

    let mut ms: Vec<Measurement> = Vec::new();
    let mut size_rows: Vec<Json> = Vec::new();
    let mut speedup_at_1024 = 0.0;
    // (n, m, bsz, blocked sim_cross median, blocked Gram median) per size,
    // for the SIMD-vs-scalar-blocked comparison below
    let mut scalar_blk: Vec<(usize, usize, usize, f64, f64)> = Vec::new();
    for &n in sizes {
        // memory-vector and chunk axes capped like the paper's grid
        let m = n.min(256);
        let bsz = n.min(256);
        let d = random_mat(m, n, 1);
        let x = random_mat(bsz, n, 2);

        // accuracy gates first (one evaluation each)
        let cross_diff = containerstress::mset::sim_cross(&d, &x).max_abs_diff(&sim_cross_ref(&d, &x));
        let gram_diff = containerstress::mset::sim_matrix(&d).max_abs_diff(&sim_matrix_ref(&d));
        assert!(
            cross_diff <= MAX_KERNEL_DIFF,
            "n={n}: blocked sim_cross diverged from reference by {cross_diff}"
        );
        assert!(
            gram_diff <= MAX_KERNEL_DIFF,
            "n={n}: blocked sim_matrix diverged from reference by {gram_diff}"
        );

        let units = (m * bsz) as f64;
        let rc = b.run_with_units(&format!("ref_sim_cross_n{n}"), units, || {
            sim_cross_ref(&d, &x)
        });
        let bc = b.run_with_units(&format!("blk_sim_cross_n{n}"), units, || {
            containerstress::mset::sim_cross(&d, &x)
        });
        let gunits = (m * m) as f64 / 2.0;
        let rg = b.run_with_units(&format!("ref_gram_n{n}"), gunits, || sim_matrix_ref(&d));
        let bg = b.run_with_units(&format!("blk_gram_n{n}"), gunits, || {
            containerstress::mset::sim_matrix(&d)
        });

        let cross_speedup = rc.stats.median / bc.stats.median;
        let gram_speedup = rg.stats.median / bg.stats.median;
        let combined =
            (rc.stats.median + rg.stats.median) / (bc.stats.median + bg.stats.median);
        println!(
            "n={n} (m={m}, B={bsz}): sim_cross {cross_speedup:.2}×, gram {gram_speedup:.2}×, \
             combined {combined:.2}× (diffs {cross_diff:.2e}/{gram_diff:.2e})"
        );
        if n == 1024 {
            speedup_at_1024 = combined;
        }
        scalar_blk.push((n, m, bsz, bc.stats.median, bg.stats.median));
        size_rows.push(Json::obj(vec![
            ("n", Json::Num(n as f64)),
            ("m", Json::Num(m as f64)),
            ("b", Json::Num(bsz as f64)),
            ("backend", Json::Str("scalar".into())),
            ("ref_sim_cross_s", Json::Num(rc.stats.median)),
            ("blk_sim_cross_s", Json::Num(bc.stats.median)),
            ("ref_gram_s", Json::Num(rg.stats.median)),
            ("blk_gram_s", Json::Num(bg.stats.median)),
            ("speedup_sim_cross", Json::Num(cross_speedup)),
            ("speedup_gram", Json::Num(gram_speedup)),
            ("speedup_combined", Json::Num(combined)),
            ("max_diff_sim_cross", Json::Num(cross_diff)),
            ("max_diff_gram", Json::Num(gram_diff)),
        ]));
        ms.extend([rc, bc, rg, bg]);
    }
    assert!(
        speedup_at_1024 >= MIN_KERNEL_SPEEDUP,
        "blocked sim_cross+Gram at n=1024 is only {speedup_at_1024:.2}× the reference \
         (floor {MIN_KERNEL_SPEEDUP}×)"
    );

    // --- SIMD tier vs scalar blocked --------------------------------------
    let mut simd_speedup_at_1024 = 0.0;
    match simd_tier {
        None => println!(
            "no SIMD tier on this host (need AVX2+FMA on x86_64 or NEON on aarch64); \
             skipping SIMD floors"
        ),
        Some(tier) => {
            simd::install(simd::BackendRequest::Simd, "bench").expect("detected tier installs");
            for &(n, m, bsz, blk_cross_s, blk_gram_s) in &scalar_blk {
                let d = random_mat(m, n, 1);
                let x = random_mat(bsz, n, 2);
                // tolerance-mode accuracy gate: same ≤ 1e-10 bound vs the
                // naive references as the scalar tier
                let cross_diff = containerstress::mset::sim_cross(&d, &x)
                    .max_abs_diff(&sim_cross_ref(&d, &x));
                let gram_diff =
                    containerstress::mset::sim_matrix(&d).max_abs_diff(&sim_matrix_ref(&d));
                assert!(
                    cross_diff <= MAX_KERNEL_DIFF,
                    "n={n}: SIMD sim_cross diverged from reference by {cross_diff}"
                );
                assert!(
                    gram_diff <= MAX_KERNEL_DIFF,
                    "n={n}: SIMD sim_matrix diverged from reference by {gram_diff}"
                );
                let units = (m * bsz) as f64;
                let sc = b.run_with_units(&format!("simd_sim_cross_n{n}"), units, || {
                    containerstress::mset::sim_cross(&d, &x)
                });
                let gunits = (m * m) as f64 / 2.0;
                let sg = b.run_with_units(&format!("simd_gram_n{n}"), gunits, || {
                    containerstress::mset::sim_matrix(&d)
                });
                let cross_speedup = blk_cross_s / sc.stats.median;
                let gram_speedup = blk_gram_s / sg.stats.median;
                let combined =
                    (blk_cross_s + blk_gram_s) / (sc.stats.median + sg.stats.median);
                println!(
                    "n={n} [{}]: sim_cross {cross_speedup:.2}×, gram {gram_speedup:.2}× vs \
                     scalar blocked, combined {combined:.2}× (diffs {cross_diff:.2e}/{gram_diff:.2e})",
                    tier.isa()
                );
                if n == 1024 {
                    simd_speedup_at_1024 = combined;
                }
                size_rows.push(Json::obj(vec![
                    ("n", Json::Num(n as f64)),
                    ("m", Json::Num(m as f64)),
                    ("b", Json::Num(bsz as f64)),
                    ("backend", Json::Str(tier.isa().into())),
                    ("simd_sim_cross_s", Json::Num(sc.stats.median)),
                    ("simd_gram_s", Json::Num(sg.stats.median)),
                    ("speedup_sim_cross_vs_blk", Json::Num(cross_speedup)),
                    ("speedup_gram_vs_blk", Json::Num(gram_speedup)),
                    ("speedup_combined_vs_blk", Json::Num(combined)),
                    ("max_diff_sim_cross", Json::Num(cross_diff)),
                    ("max_diff_gram", Json::Num(gram_diff)),
                ]));
                ms.extend([sc, sg]);
            }
            assert!(
                simd_speedup_at_1024 >= MIN_SIMD_SPEEDUP,
                "SIMD ({}) sim_cross+Gram at n=1024 is only {simd_speedup_at_1024:.2}× the \
                 scalar blocked tier (floor {MIN_SIMD_SPEEDUP}×)",
                tier.isa()
            );
            // back to the deterministic scalar tier for the e2e floor
            simd::install(simd::BackendRequest::Scalar, "bench")
                .expect("scalar install cannot fail");
        }
    }

    // --- end-to-end native trial -----------------------------------------
    // A surveillance-heavy cell, mirroring the native run_trial body.
    let (tn, tm, tobs) = (32usize, 64usize, 4096usize);
    let xhat_ref = reference_trial(tn, tm, tobs, 7);
    let xhat_new = production_trial(tn, tm, tobs, 7);
    let e2e_diff = xhat_ref.max_abs_diff(&xhat_new);
    assert!(
        e2e_diff < 1e-7,
        "production trial estimate diverged from the reference pipeline: {e2e_diff}"
    );
    let rt = b.run(&format!("ref_trial_n{tn}_m{tm}_obs{tobs}"), || {
        black_box(reference_trial(tn, tm, tobs, 7))
    });
    let pt = b.run(&format!("blk_trial_n{tn}_m{tm}_obs{tobs}"), || {
        black_box(production_trial(tn, tm, tobs, 7))
    });
    let e2e_speedup = rt.stats.median / pt.stats.median;
    println!(
        "end-to-end native trial (n={tn}, m={tm}, obs={tobs}): {:.3}s → {:.3}s = {e2e_speedup:.2}× \
         (estimate diff {e2e_diff:.2e})",
        rt.stats.median, pt.stats.median
    );
    assert!(
        e2e_speedup >= MIN_E2E_SPEEDUP,
        "end-to-end native trial is only {e2e_speedup:.2}× the reference pipeline \
         (floor {MIN_E2E_SPEEDUP}×)"
    );
    let (ref_trial_s, blk_trial_s) = (rt.stats.median, pt.stats.median);
    ms.push(rt);
    ms.push(pt);

    // --- measured CPU calibration -----------------------------------------
    // Effective CPU GFLOP/s per backend from full `MsetPlugin` fit/estimate
    // cells; the emitted rows are what `accel::measured_cpu_ref()` hands to
    // `recommend` in place of the paper-anchored analytic CpuRef.
    let cal_cells: &[(usize, usize, usize)] = &[(32, 128, 2048), (64, 256, 4096)];
    let mut cal_rows: Vec<Json> = Vec::new();
    let mut cal_backends = vec![(simd::BackendRequest::Scalar, "scalar")];
    if let Some(tier) = simd_tier {
        cal_backends.push((simd::BackendRequest::Simd, tier.isa()));
    }
    for &(req, isa) in &cal_backends {
        simd::install(req, "bench").expect("calibration tier installs");
        let mut train_cells: Vec<(f64, f64)> = Vec::new();
        let mut surveil_cells: Vec<(f64, f64)> = Vec::new();
        for &(n, m, obs) in cal_cells {
            let train_ds = synthesize(&TpssConfig::sized(n, obs.max(2 * m)), 21);
            let probe_ds = synthesize(&TpssConfig::sized(n, obs), 22);
            let fit = b.run(&format!("cal_fit_{isa}_n{n}_m{m}"), || {
                let mut p = MsetPlugin::default();
                p.fit(&train_ds.data, m).expect("fit");
                black_box(p)
            });
            let mut plugin = MsetPlugin::default();
            plugin.fit(&train_ds.data, m).expect("fit");
            let est = b.run(&format!("cal_est_{isa}_n{n}_obs{obs}"), || {
                black_box(plugin.estimate(&probe_ds.data))
            });
            train_cells.push((
                accel::total_flops(&accel::train_routines(n, m)),
                fit.stats.median,
            ));
            surveil_cells.push((
                accel::total_flops(&accel::surveil_routines(n, m, obs, accel::GPU_CHUNK)),
                est.stats.median,
            ));
            ms.push(fit);
            ms.push(est);
        }
        let train_eff =
            accel::calibrate_cpu_eff(&train_cells).expect("measured training cells");
        let surveil_eff =
            accel::calibrate_cpu_eff(&surveil_cells).expect("measured surveillance cells");
        println!(
            "calibration [{isa}]: train {:.2} GFLOP/s, surveil {:.2} GFLOP/s",
            train_eff / 1e9,
            surveil_eff / 1e9
        );
        cal_rows.push(Json::obj(vec![
            ("backend", Json::Str(isa.into())),
            ("train_eff_flops", Json::Num(train_eff)),
            ("surveil_eff_flops", Json::Num(surveil_eff)),
        ]));
    }
    simd::install(simd::BackendRequest::Scalar, "bench").expect("scalar install cannot fail");

    // --- emit artifacts ---------------------------------------------------
    let json = Json::obj(vec![
        ("bench", Json::Str("kernel_hotpath".into())),
        ("quick", Json::Bool(quick)),
        ("sizes", Json::Arr(size_rows)),
        (
            "e2e",
            Json::obj(vec![
                ("n", Json::Num(tn as f64)),
                ("m", Json::Num(tm as f64)),
                ("obs", Json::Num(tobs as f64)),
                ("ref_trial_s", Json::Num(ref_trial_s)),
                ("blk_trial_s", Json::Num(blk_trial_s)),
                ("speedup", Json::Num(e2e_speedup)),
                ("estimate_diff", Json::Num(e2e_diff)),
            ]),
        ),
        ("calibration", Json::Arr(cal_rows)),
        (
            "asserted",
            Json::obj(vec![
                ("max_kernel_diff", Json::Num(MAX_KERNEL_DIFF)),
                ("min_kernel_speedup_n1024", Json::Num(MIN_KERNEL_SPEEDUP)),
                ("min_e2e_speedup", Json::Num(MIN_E2E_SPEEDUP)),
                ("kernel_speedup_n1024", Json::Num(speedup_at_1024)),
                (
                    "simd_backend",
                    match simd_tier {
                        Some(t) => Json::Str(t.isa().into()),
                        None => Json::Null,
                    },
                ),
                (
                    "min_simd_speedup_n1024",
                    if simd_tier.is_some() {
                        Json::Num(MIN_SIMD_SPEEDUP)
                    } else {
                        Json::Null
                    },
                ),
                (
                    "simd_speedup_n1024",
                    if simd_tier.is_some() {
                        Json::Num(simd_speedup_at_1024)
                    } else {
                        Json::Null
                    },
                ),
            ]),
        ),
    ]);
    let dir = std::path::Path::new("results");
    report::write(dir, "BENCH_kernel.json", &json.to_pretty()).unwrap();
    println!("{}", table(&ms));
    write_csv("results/kernel_hotpath.csv", &ms).unwrap();
    println!("kernel_hotpath done → results/BENCH_kernel.json, results/kernel_hotpath.csv");
}
