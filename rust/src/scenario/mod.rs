//! **Fleet scenario engine** — trace-driven multi-tenant what-if
//! simulation over surface oracles.
//!
//! The paper's introduction poses the vendor-side question ContainerStress
//! exists to answer: *which shape do we hand each customer, and when does
//! pre-scoping beat elastic growth?* A single fitted sweep answers it for
//! one tenant at one point in time; this subsystem answers it for a
//! **fleet** — hundreds of tenants arriving, growing, cycling and spiking
//! over months — without re-running a single Monte Carlo trial the sweep
//! cache already holds:
//!
//! - [`spec`]   — the JSON scenario specification: scenarios are *data*
//!   (tenant arrival process, demand generators, workload drift, policy
//!   list), not code;
//! - [`trace`]  — deterministic-RNG workload generators: Poisson tenant
//!   arrivals, exponential/step growth, diurnal cycles, flash crowds,
//!   per-tenant jitter and workload-parameter drift over the
//!   `(n_signals, n_memvec, n_obs)` grid;
//! - [`oracle`] — the surface oracle: per-epoch "cost of tenant *w* on
//!   shape *s*" queries answered from already-fitted
//!   [`crate::surface::ResponseSurface`]s, falling back to cached sweep
//!   cells, and only enqueueing real Monte Carlo trials (through the
//!   shared [`crate::util::threadpool::TrialExecutor`]) for
//!   out-of-domain queries;
//! - [`fleet`]  — the simulation engine: replays a scenario against
//!   pluggable placement/scaling policies (pre-scoped fixed shape,
//!   reactive threshold autoscaler, predictive oracle-driven scaler) and
//!   emits per-policy cost-over-time, SLA-violation counts, migration
//!   counts, and a Pareto (cost vs violations) comparison through
//!   [`crate::recommend`].
//!
//! The single-tenant elasticity simulator (`shapes::elastic`) is the
//! degenerate case: its loops were absorbed into [`fleet`] and it now
//! delegates, so a one-tenant scenario reproduces the paper's
//! reactive-vs-pre-scoped crossover bit for bit.
//!
//! Surfaced end to end: `containerstress simulate`, the service's
//! `POST /v1/scenarios` + `GET /v1/scenarios/{id}` (jobs on the shared
//! executor with live progress and cancellation), and
//! `benches/fleet_scenarios.rs`.

pub mod fleet;
pub mod oracle;
pub mod spec;
pub mod trace;

pub use fleet::{
    run_scenario, run_scenario_executor, PolicyOutcome, PredictivePolicy, ScenarioOutcome,
    ScenarioProgress, ScenarioSnapshot,
};
pub use oracle::{Backstop, MeasureCtx, OracleSnapshot, SurfaceOracle};
pub use spec::{ArrivalSpec, DemandKind, DemandSpec, PolicySpec, ScenarioSpec, WorkloadSpec};
pub use trace::Tenant;
