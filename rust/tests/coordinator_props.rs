//! Property tests over coordinator invariants (DESIGN.md §8), using the
//! in-repo property harness (`util::prop`) — `proptest` is unavailable in
//! the offline build environment.
//!
//! All properties run on the native backend (no artifacts required) so
//! this suite is independent of `make artifacts`.

use containerstress::coordinator::{run_sweep, run_sweep_cached, Backend, SweepSpec};
use containerstress::util::prop::{forall, forall_res};
use containerstress::util::rng::Rng;

/// Generate a small random sweep spec (kept tiny: each case really runs).
fn gen_spec(rng: &mut Rng) -> SweepSpec {
    let pick = |rng: &mut Rng, opts: &[usize], k: usize| -> Vec<usize> {
        let mut v = rng.sample_indices(opts.len(), k.min(opts.len()));
        v.sort_unstable();
        v.into_iter().map(|i| opts[i]).collect()
    };
    let k_sig = 1 + rng.range_usize(0, 2);
    let k_mem = 1 + rng.range_usize(0, 2);
    let k_obs = 1 + rng.range_usize(0, 2);
    SweepSpec {
        signals: pick(rng, &[2, 3, 4, 6, 8], k_sig),
        memvecs: pick(rng, &[4, 8, 12, 16, 24], k_mem),
        obs: pick(rng, &[16, 32, 64], k_obs),
        trials: 1 + rng.range_usize(0, 2),
        seed: rng.next_u64(),
        model: "mset2".into(),
        workers: 1 + rng.range_usize(0, 3),
        ..SweepSpec::default()
    }
}

#[test]
fn prop_grid_coverage_exact() {
    forall_res(
        "every grid cell appears exactly once",
        12,
        gen_spec,
        |spec| {
            let res = run_sweep(spec, Backend::Native).map_err(|e| e.to_string())?;
            let expect = spec.signals.len() * spec.memvecs.len() * spec.obs.len();
            if res.cells.len() != expect {
                return Err(format!("{} cells != {expect}", res.cells.len()));
            }
            // no duplicates
            let mut seen = std::collections::HashSet::new();
            for c in &res.cells {
                if !seen.insert((c.key.n, c.key.m, c.key.obs)) {
                    return Err(format!("duplicate cell {:?}", c.key));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_constraint_cells_are_gaps_and_only_those() {
    forall_res(
        "m < 2n cells are gaps; all others measured",
        12,
        gen_spec,
        |spec| {
            let res = run_sweep(spec, Backend::Native).map_err(|e| e.to_string())?;
            for c in &res.cells {
                let should_gap = c.key.m < 2 * c.key.n;
                if c.violated != should_gap {
                    return Err(format!(
                        "cell {:?}: violated={} expected {}",
                        c.key, c.violated, should_gap
                    ));
                }
                if should_gap && (c.train.is_some() || c.surveil.is_some()) {
                    return Err(format!("gap cell {:?} has measurements", c.key));
                }
                if !should_gap {
                    let t = c.train.as_ref().ok_or("missing train")?;
                    let s = c.surveil.as_ref().ok_or("missing surveil")?;
                    if t.n != spec.trials || s.n != spec.trials {
                        return Err(format!(
                            "cell {:?}: {}/{} trials, expected {}",
                            c.key, t.n, s.n, spec.trials
                        ));
                    }
                    if !(t.median > 0.0 && s.median > 0.0) {
                        return Err(format!("cell {:?}: non-positive cost", c.key));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_worker_count_does_not_change_structure() {
    forall_res(
        "results independent of worker parallelism",
        6,
        |rng| {
            let mut s = gen_spec(rng);
            s.trials = 1;
            s
        },
        |spec| {
            let mut s1 = spec.clone();
            s1.workers = 1;
            let mut s4 = spec.clone();
            s4.workers = 4;
            let a = run_sweep(&s1, Backend::Native).map_err(|e| e.to_string())?;
            let b = run_sweep(&s4, Backend::Native).map_err(|e| e.to_string())?;
            if a.gap_cells() != b.gap_cells() {
                return Err("gap cells differ with worker count".into());
            }
            let keys_a: Vec<_> = a.cells.iter().map(|c| c.key).collect();
            let keys_b: Vec<_> = b.cells.iter().map(|c| c.key).collect();
            if keys_a != keys_b {
                return Err("cell order differs with worker count".into());
            }
            Ok(())
        },
    );
}

/// Random adaptive spec: small grids, pilot 2, varied CI target and cap.
fn gen_adaptive_spec(rng: &mut Rng) -> SweepSpec {
    let mut s = gen_spec(rng);
    s.trials = 1; // ignored in adaptive mode (the cap governs)
    s.pilot_trials = 2;
    s.ci_target = 0.25 + 0.25 * rng.range_usize(0, 3) as f64; // 0.25 | 0.5 | 0.75
    s.max_trials = 3 + rng.range_usize(0, 2); // 3..=4
    s.interpolate = rng.range_usize(0, 2) == 1;
    s
}

#[test]
fn prop_adaptive_trials_bounded_and_structure_thread_independent() {
    forall_res(
        "planner: pilot ≤ trials ≤ max; grid structure independent of workers",
        8,
        gen_adaptive_spec,
        |spec| {
            let res = run_sweep(spec, Backend::Native).map_err(|e| e.to_string())?;
            let mut other = spec.clone();
            other.workers = (spec.workers % 4) + 1; // a different thread count
            let res2 = run_sweep(&other, Backend::Native).map_err(|e| e.to_string())?;

            // The deterministic part of the planner — which cells exist,
            // which are gaps, and in what order — must not depend on the
            // worker count (trial seeds are content-derived; only the
            // noise-driven allocation totals may differ).
            if res.gap_cells() != res2.gap_cells() {
                return Err("gap cells differ with worker count".into());
            }
            let keys: Vec<_> = res.cells.iter().map(|c| c.key).collect();
            let keys2: Vec<_> = res2.cells.iter().map(|c| c.key).collect();
            if keys != keys2 {
                return Err("cell order differs with worker count".into());
            }

            let max = spec.effective_max_trials();
            for c in &res.cells {
                if c.violated {
                    if c.interpolated {
                        return Err(format!("gap cell {:?} marked interpolated", c.key));
                    }
                    continue;
                }
                let t = c.train.as_ref().ok_or("missing train")?;
                let s = c.surveil.as_ref().ok_or("missing surveil")?;
                if t.n != s.n {
                    return Err(format!(
                        "cell {:?}: phases disagree on trials ({} vs {})",
                        c.key, t.n, s.n
                    ));
                }
                if t.n < spec.pilot_trials || t.n > max {
                    return Err(format!(
                        "cell {:?}: {} trials outside [{}, {max}]",
                        c.key, t.n, spec.pilot_trials
                    ));
                }
                if c.interpolated && t.n != spec.pilot_trials {
                    return Err(format!(
                        "interpolated cell {:?} ran {} trials, expected the pilot {}",
                        c.key, t.n, spec.pilot_trials
                    ));
                }
                if c.interpolated && !spec.interpolate {
                    return Err(format!(
                        "cell {:?} interpolated with interpolate=false",
                        c.key
                    ));
                }
                // Termination invariant: a measured (non-interpolated) cell
                // stopped because it met the CI target or hit the cap.
                if !c.interpolated && t.n < max {
                    let rel = |s: &containerstress::util::Summary| {
                        // Summary stores the population std; convert to the
                        // sample std the planner uses.
                        let n = s.n as f64;
                        let sample_std = s.std * (n / (n - 1.0)).sqrt();
                        1.96 * sample_std / (n.sqrt() * s.mean)
                    };
                    // small tolerance: the planner sums raw costs in trial
                    // order, Summary in sorted order — FP rounding differs
                    let target = spec.ci_target * (1.0 + 1e-9);
                    if rel(t) > target || rel(s) > target {
                        return Err(format!(
                            "cell {:?} stopped at {} trials without meeting the CI target",
                            c.key, t.n
                        ));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_samples_match_measured_cells() {
    forall(
        "surface samples = non-gap cells",
        10,
        gen_spec,
        |spec| {
            let res = run_sweep(spec, Backend::Native).unwrap();
            let gaps = res.gap_cells().len();
            res.samples("train").len() == res.cells.len() - gaps
                && res.samples("surveil").len() == res.cells.len() - gaps
        },
    );
}

#[test]
fn prop_aggregation_permutation_invariant() {
    // Summary statistics must not depend on trial completion order — the
    // engine keys results by cell, so shuffling the work list is safe.
    use containerstress::util::Summary;
    forall_res(
        "Summary is permutation invariant",
        50,
        |rng| {
            let n = 2 + rng.range_usize(0, 8);
            let xs: Vec<f64> = (0..n).map(|_| rng.f64() * 10.0).collect();
            let mut shuffled = xs.clone();
            rng.shuffle(&mut shuffled);
            (xs, shuffled)
        },
        |(a, b)| {
            let sa = Summary::of(a);
            let sb = Summary::of(b);
            if (sa.median - sb.median).abs() > 1e-12 || (sa.mean - sb.mean).abs() > 1e-12 {
                return Err("summary changed under permutation".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_concurrent_jobs_preserve_exhaustive_schedule() {
    use containerstress::coordinator::jobs::ScopingService;
    use containerstress::coordinator::CellStore;
    use containerstress::service::cache::SweepCache;
    use std::sync::Arc;
    forall_res(
        "two concurrent exhaustive jobs reproduce the deterministic per-cell schedule",
        4,
        |rng| {
            let mut a = gen_spec(rng);
            a.trials = 1 + rng.range_usize(0, 1);
            let mut b = gen_spec(rng);
            // Distinct root seeds keep the jobs' cache keys disjoint, so
            // each job's stored cells are unambiguously its own.
            b.seed = a.seed ^ 0x9E37_79B9;
            b.trials = 1 + rng.range_usize(0, 1);
            (a, b)
        },
        |(a, b)| {
            let cache = Arc::new(SweepCache::in_memory());
            let svc = ScopingService::start_with_cache(
                Backend::Native,
                8,
                Some(Arc::clone(&cache) as Arc<dyn CellStore>),
            );
            let ia = svc.submit(a.clone()).map_err(|e| e.to_string())?;
            let ib = svc.submit(b.clone()).map_err(|e| e.to_string())?;
            let ra = svc.wait(ia).map_err(|e| e.to_string())?;
            let rb = svc.wait(ib).map_err(|e| e.to_string())?;

            // Structure matches a solo reference run: same cells, same
            // gaps, same per-cell trial counts.
            let solo = run_sweep(a, Backend::Native).map_err(|e| e.to_string())?;
            if ra.gap_cells() != solo.gap_cells() {
                return Err("gap cells differ under concurrent execution".into());
            }
            for (x, y) in ra.cells.iter().zip(&solo.cells) {
                if x.key != y.key {
                    return Err(format!("cell order differs: {:?} vs {:?}", x.key, y.key));
                }
                let (nx, ny) = (
                    x.train.as_ref().map(|s| s.n),
                    y.train.as_ref().map(|s| s.n),
                );
                if nx != ny {
                    return Err(format!("cell {:?}: trial counts {nx:?} vs {ny:?}", x.key));
                }
            }

            // Bit-identical determinism: replaying each spec against the
            // shared store must serve every cell verbatim from what its
            // concurrent job measured — equal summaries bit-for-bit proves
            // the executor ran exactly the content-derived trial schedule,
            // in trial-index order, for both jobs at once.
            for (spec, res) in [(a, &ra), (b, &rb)] {
                let replay = run_sweep_cached(spec, Backend::Native, Some(&*cache))
                    .map_err(|e| e.to_string())?;
                for (x, y) in res.cells.iter().zip(&replay.cells) {
                    if x.key != y.key || x.violated != y.violated {
                        return Err(format!("replay structure differs at {:?}", x.key));
                    }
                    if x.violated {
                        continue;
                    }
                    let (xt, yt) = (x.train.as_ref().unwrap(), y.train.as_ref().unwrap());
                    let (xs, ys) = (x.surveil.as_ref().unwrap(), y.surveil.as_ref().unwrap());
                    if xt.n != yt.n
                        || xt.median != yt.median
                        || xt.mean != yt.mean
                        || xs.median != ys.median
                        || xs.mean != ys.mean
                    {
                        return Err(format!(
                            "cell {:?}: summaries not bit-identical on replay",
                            x.key
                        ));
                    }
                }
            }
            svc.shutdown();
            Ok(())
        },
    );
}

#[test]
fn prop_scoping_service_completes_all_jobs() {
    use containerstress::coordinator::jobs::ScopingService;
    forall_res(
        "every submitted job completes",
        4,
        |rng| {
            let specs: Vec<SweepSpec> = (0..1 + rng.range_usize(0, 3))
                .map(|_| {
                    let mut s = gen_spec(rng);
                    s.trials = 1;
                    s.signals.truncate(1);
                    s.memvecs.truncate(1);
                    s.obs.truncate(1);
                    s
                })
                .collect();
            specs
        },
        |specs| {
            let svc = ScopingService::start(Backend::Native, 16);
            let ids: Vec<_> = specs
                .iter()
                .map(|s| svc.submit(s.clone()).map_err(|e| e.to_string()))
                .collect::<Result<_, _>>()?;
            for id in ids {
                svc.wait(id).map_err(|e| e.to_string())?;
            }
            Ok(())
        },
    );
}
