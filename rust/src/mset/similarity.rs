//! The MSET similarity operator ⊗ — the paper's computational hot-spot
//! ("a non-linear matrix binary operation", §II.D), the routine NVIDIA
//! hand-wrote in CUDA and we re-think as a Pallas/MXU kernel at L1.
//!
//! Definition (shared verbatim with `python/compile/kernels/ref.py`):
//!
//! ```text
//! s(a, b) = 1 / (1 + ‖a − b‖₂ / (γ·√n))      γ = 0.5
//! ```
//!
//! Bounded in (0, 1], s(a, a) = 1, and scale-normalised by √n so kernel
//! bandwidth is independent of the signal count — which is what lets the
//! bucket router zero-pad the signal dimension without changing results
//! (padding contributes 0 to the squared distance).
//!
//! The production entry points ([`sim_matrix`], [`sim_cross`] and their
//! `_into` variants) compute ‖a−b‖² via the ‖a‖² + ‖b‖² − 2a·b expansion
//! over the blocked [`crate::linalg::kernel`] GEMM core — the exact
//! formulation the L1 Pallas kernel uses on the MXU. The pre-blocked
//! per-pair loops survive as [`sim_matrix_ref`]/[`sim_cross_ref`], the
//! oracles the property tests and `benches/kernel_hotpath.rs` gate the
//! blocked path against. By the kernel core's bit-stability contract,
//! `sim_cross(d, d)` equals `sim_matrix(d)` *exactly* (unit diagonal
//! included), and zero-padding the signal dimension leaves every
//! similarity bit-identical.
//!
//! Under the opt-in SIMD kernel tier (`--kernel-backend simd`, see
//! [`crate::linalg::simd`]) the dot products underneath run in
//! *tolerance mode*: similarities agree with the references to ≤ 1e-10
//! rather than bit-for-bit, and padding invariance holds to the same
//! tolerance. The cross-entry-point identities survive exactly even
//! then — `sim_cross(d, d)` still equals `sim_matrix(d)` bitwise and the
//! diagonal stays exactly 1 — because both entry points share one
//! internally bit-consistent dot sequence. The scalar default keeps
//! every bit-exact guarantee above.

use crate::linalg::{kernel, Mat, Workspace};

/// Kernel bandwidth γ (dimensionless).
pub const GAMMA: f64 = 0.5;

/// Similarity of two vectors. `n_real` is the *unpadded* signal count used
/// for bandwidth normalisation.
#[inline]
pub fn sim(a: &[f64], b: &[f64], n_real: usize) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut d2 = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        d2 += d * d;
    }
    1.0 / (1.0 + d2.sqrt() / (GAMMA * (n_real as f64).sqrt()))
}

/// Shared epilogue: squared distance (already clamped ≥ 0) → similarity.
#[inline]
fn sim_of_dist2(d2: f64, bw: f64) -> f64 {
    1.0 / (1.0 + d2.sqrt() / bw)
}

/// Similarity bandwidth γ·√n for an unpadded signal count.
#[inline]
fn bandwidth(n_real: usize) -> f64 {
    GAMMA * (n_real as f64).sqrt()
}

/// Symmetric similarity matrix `S[i][j] = s(D[i], D[j])` for a memory
/// matrix stored rows-as-vectors (`m × n`), via the blocked Gram core
/// (see [`sim_matrix_into`]).
pub fn sim_matrix(d: &Mat) -> Mat {
    Workspace::with(|ws| {
        let mut s = Mat::zeros(0, 0);
        sim_matrix_into(&mut s, d, ws);
        s
    })
}

/// [`sim_matrix`] into a caller-owned matrix: one blocked `syrk` for the
/// Gram half-product (norms come off its diagonal), then the similarity
/// epilogue in place. Exactly symmetric, diagonal exactly 1, and
/// bit-identical to [`sim_cross_into`]`(d, d)`.
pub fn sim_matrix_into(s: &mut Mat, d: &Mat, ws: &mut Workspace) {
    kernel::dist2_sym_into(s, d, ws);
    let bw = bandwidth(d.cols);
    for v in s.data.iter_mut() {
        *v = sim_of_dist2(*v, bw);
    }
}

/// Cross similarity `K[i][b] = s(D[i], X[b])` between memory vectors
/// (`m × n`) and an observation chunk (`B × n`). Result is `m × B`.
pub fn sim_cross(d: &Mat, x: &Mat) -> Mat {
    Workspace::with(|ws| {
        let mut k = Mat::zeros(0, 0);
        sim_cross_into(&mut k, d, x, d.cols, ws);
        k
    })
}

/// [`sim_cross`] into a caller-owned matrix over the blocked Gram core.
/// `n_real` is the unpadded signal count for bandwidth normalisation
/// (pass `d.cols` when nothing is padded) — zero-padded columns leave the
/// result bit-identical, the invariant the bucket router relies on.
pub fn sim_cross_into(k: &mut Mat, d: &Mat, x: &Mat, n_real: usize, ws: &mut Workspace) {
    assert_eq!(d.cols, x.cols, "signal count mismatch");
    kernel::dist2_cross_into(k, d, x, ws);
    let bw = bandwidth(n_real);
    for v in k.data.iter_mut() {
        *v = sim_of_dist2(*v, bw);
    }
}

/// Transposed cross similarity `Kᵀ[b][i] = s(X[b], D[i])` (`B × m`) —
/// the layout the streaming estimate wants (each observation's weight
/// row is contiguous). Bit-identical to transposing [`sim_cross_into`].
pub fn sim_cross_t_into(kt: &mut Mat, x: &Mat, d: &Mat, n_real: usize, ws: &mut Workspace) {
    assert_eq!(d.cols, x.cols, "signal count mismatch");
    kernel::dist2_cross_into(kt, x, d, ws);
    let bw = bandwidth(n_real);
    for v in kt.data.iter_mut() {
        *v = sim_of_dist2(*v, bw);
    }
}

/// Reference [`sim_matrix`]: per-pair [`sim`] loops exploiting symmetry —
/// the pre-blocked implementation, kept as the oracle for the property
/// tests and the `kernel_hotpath` bench.
pub fn sim_matrix_ref(d: &Mat) -> Mat {
    let m = d.rows;
    let n = d.cols;
    let mut s = Mat::zeros(m, m);
    for i in 0..m {
        s[(i, i)] = 1.0;
        for j in 0..i {
            let v = sim(d.row(i), d.row(j), n);
            s[(i, j)] = v;
            s[(j, i)] = v;
        }
    }
    s
}

/// Reference [`sim_cross`]: the naive per-pair Euclidean loop (the
/// paper's pre-GPU formulation), kept as the oracle for the property
/// tests and the `kernel_hotpath` bench.
pub fn sim_cross_ref(d: &Mat, x: &Mat) -> Mat {
    assert_eq!(d.cols, x.cols, "signal count mismatch");
    let m = d.rows;
    let b = x.rows;
    let n = d.cols;
    let mut k = Mat::zeros(m, b);
    for i in 0..m {
        let di = d.row(i);
        for j in 0..b {
            k[(i, j)] = sim(di, x.row(j), n);
        }
    }
    k
}

/// Gram-trick variant of [`sim_cross`] — computes ‖a−b‖² as
/// ‖a‖² + ‖b‖² − 2aᵀb with a matmul. Historically the "fast"
/// formulation; the production path now fuses the same expansion into
/// the blocked kernel core ([`sim_cross_into`]). Kept for the kernel
/// ablation bench and as a second oracle for the Python kernel.
pub fn sim_cross_gram(d: &Mat, x: &Mat) -> Mat {
    assert_eq!(d.cols, x.cols);
    let m = d.rows;
    let b = x.rows;
    let n = d.cols;
    let d_norm2: Vec<f64> = (0..m)
        .map(|i| d.row(i).iter().map(|v| v * v).sum())
        .collect();
    let x_norm2: Vec<f64> = (0..b)
        .map(|j| x.row(j).iter().map(|v| v * v).sum())
        .collect();
    let cross = d.matmul(&x.transpose()); // m × B
    let mut k = Mat::zeros(m, b);
    let bw = GAMMA * (n as f64).sqrt();
    for i in 0..m {
        for j in 0..b {
            let d2 = (d_norm2[i] + x_norm2[j] - 2.0 * cross[(i, j)]).max(0.0);
            k[(i, j)] = 1.0 / (1.0 + d2.sqrt() / bw);
        }
    }
    k
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_mat(rows: usize, cols: usize, seed: u64) -> Mat {
        let mut rng = Rng::new(seed);
        let mut m = Mat::zeros(rows, cols);
        rng.fill_gauss(&mut m.data);
        m
    }

    #[test]
    fn self_similarity_is_one() {
        let v = vec![1.0, -2.0, 3.0];
        assert_eq!(sim(&v, &v, 3), 1.0);
    }

    #[test]
    fn similarity_bounded_and_monotone() {
        let a = vec![0.0; 4];
        let near = vec![0.1; 4];
        let far = vec![5.0; 4];
        let s_near = sim(&a, &near, 4);
        let s_far = sim(&a, &far, 4);
        assert!(s_near > s_far);
        assert!(s_far > 0.0 && s_near < 1.0);
    }

    #[test]
    fn padding_invariance() {
        // zero-padding the signal dimension (with n_real fixed) must not
        // change similarity — the property the bucket router relies on.
        let a = vec![1.0, 2.0, 3.0];
        let b = vec![0.5, 2.5, 2.0];
        let mut ap = a.clone();
        let mut bp = b.clone();
        ap.extend([0.0; 5]);
        bp.extend([0.0; 5]);
        assert!((sim(&a, &b, 3) - sim(&ap, &bp, 3)).abs() < 1e-15);
    }

    #[test]
    fn sim_matrix_symmetric_unit_diag() {
        let d = random_mat(10, 4, 1);
        let s = sim_matrix(&d);
        for i in 0..10 {
            assert_eq!(s[(i, i)], 1.0);
            for j in 0..10 {
                assert!((s[(i, j)] - s[(j, i)]).abs() < 1e-15);
                assert!(s[(i, j)] > 0.0 && s[(i, j)] <= 1.0);
            }
        }
    }

    #[test]
    fn blocked_matches_reference() {
        let d = random_mat(23, 9, 5);
        let x = random_mat(14, 9, 6);
        let k = sim_cross(&d, &x);
        let kr = sim_cross_ref(&d, &x);
        assert!(
            k.max_abs_diff(&kr) < 1e-12,
            "blocked sim_cross diverged: {}",
            k.max_abs_diff(&kr)
        );
        let s = sim_matrix(&d);
        let sr = sim_matrix_ref(&d);
        assert!(
            s.max_abs_diff(&sr) < 1e-12,
            "blocked sim_matrix diverged: {}",
            s.max_abs_diff(&sr)
        );
    }

    #[test]
    fn gram_trick_matches_direct() {
        let d = random_mat(20, 7, 2);
        let x = random_mat(13, 7, 3);
        let direct = sim_cross_ref(&d, &x);
        let gram = sim_cross_gram(&d, &x);
        assert!(
            direct.max_abs_diff(&gram) < 1e-9,
            "gram formulation diverged: {}",
            direct.max_abs_diff(&gram)
        );
    }

    #[test]
    fn sim_cross_against_sim_matrix() {
        // bit-identical, not merely close: both run the same Gram core
        // and read norms from the same accumulation sequence.
        let d = random_mat(8, 3, 4);
        let k = sim_cross(&d, &d);
        let s = sim_matrix(&d);
        assert_eq!(k, s);
    }

    #[test]
    fn transposed_variant_matches() {
        let d = random_mat(12, 5, 7);
        let x = random_mat(9, 5, 8);
        let k = sim_cross(&d, &x);
        let mut kt = Mat::zeros(0, 0);
        Workspace::with(|ws| sim_cross_t_into(&mut kt, &x, &d, d.cols, ws));
        for i in 0..12 {
            for j in 0..9 {
                assert_eq!(k[(i, j)].to_bits(), kt[(j, i)].to_bits());
            }
        }
    }
}
