//! # ContainerStress
//!
//! Reproduction of *"ContainerStress: Autonomous Cloud-Node Scoping Framework
//! for Big-Data ML Use Cases"* (Wang, Gross, Subramaniam; 2020) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! - **L3 (this crate)** — the ContainerStress coordinator: nested-loop
//!   Monte Carlo sweep engine, cloud shape catalog, GPU-speedup model,
//!   response-surface methodology, and scoping recommender — plus the
//!   [`service`] layer (`containerstress serve`): a multi-tenant HTTP JSON
//!   API over the scoping-job queue with a content-addressed cell-level
//!   sweep cache, so identical grid cells are never measured twice across
//!   customer requests.
//! - **L2** — MSET2 train/surveil compute graphs written in JAX
//!   (`python/compile/model.py`), AOT-lowered to HLO text artifacts.
//! - **L1** — the similarity-matrix hot-spot as a Pallas kernel
//!   (`python/compile/kernels/similarity.py`), fused into the L2 graphs.
//!
//! The Rust binary loads the artifacts through the PJRT CPU client
//! ([`runtime`]) and never invokes Python at run time.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index.

pub mod accel;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod detect;
pub mod linalg;
pub mod metrics;
pub mod models;
pub mod mset;
pub mod recommend;
pub mod report;
pub mod runtime;
pub mod service;
pub mod shapes;
pub mod surface;
pub mod tpss;
pub mod util;
