"""Newton–Schulz in-graph inverse: convergence envelope tests.

The training graph replaces cuSOLVER's eigendecomposition with a
matmul-only Newton–Schulz iteration (DESIGN.md §7). These tests pin down
the convergence guarantee the shipped NS_ITERS relies on, across the full
conditioning range the regularisation admits (λ_min ≥ RIDGE_REL = 1e-3).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

settings.register_profile("ci", deadline=None, max_examples=10)
settings.load_profile("ci")


def spd_with_condition(m, cond, seed):
    """Random SPD matrix with prescribed condition number."""
    rng = np.random.RandomState(seed)
    q, _ = np.linalg.qr(rng.randn(m, m))
    w = np.logspace(0, -np.log10(cond), m)
    return jnp.asarray(q @ np.diag(w) @ q.T, jnp.float64)


@given(
    m=st.sampled_from([8, 32, 64]),
    log_cond=st.integers(0, 5),
    seed=st.integers(0, 10**6),
)
def test_ns_converges_across_conditioning(m, log_cond, seed):
    a = spd_with_condition(m, 10.0**log_cond, seed)
    x = model.ns_inverse(a)
    resid = np.abs(np.asarray(x @ a) - np.eye(m)).max()
    assert resid < 1e-6, f"cond=1e{log_cond}: residual {resid}"


def test_ns_worst_case_similarity_conditioning():
    """λ_min = λ = 1e-3, λ_max ≈ m — the worst case the training graph can
    produce (m up to 512 in the full profile)."""
    m = 512
    a = spd_with_condition(m, m / ref.RIDGE_REL, 0)
    # rescale so λ_max ≈ m like a similarity matrix row-sum bound
    a = a * m
    x = model.ns_inverse(a)
    resid = np.abs(np.asarray(x @ a) - np.eye(m)).max()
    assert resid < 1e-5, f"residual {resid}"


def test_ns_identity_is_fixed_point():
    eye = jnp.eye(16, dtype=jnp.float64)
    x = model.ns_inverse(eye)
    assert np.abs(np.asarray(x) - np.eye(16)).max() < 1e-12


def test_ns_iters_budget_not_excessive():
    """30 iterations must be enough AND 20 must not be (for the worst
    case) — documents why NS_ITERS is what it is."""
    m = 256
    a = spd_with_condition(m, m / ref.RIDGE_REL, 3) * m
    ok = model.ns_inverse(a, iters=30)
    assert np.abs(np.asarray(ok @ a) - np.eye(m)).max() < 1e-5
    short = model.ns_inverse(a, iters=12)
    assert np.abs(np.asarray(short @ a) - np.eye(m)).max() > 1e-5, (
        "12 iterations should NOT converge on the worst case — if it does, "
        "NS_ITERS can be lowered (perf win); update ref.NS_ITERS"
    )


@pytest.mark.parametrize("m", [16, 64])
def test_ns_matches_numpy_inverse(m):
    a = spd_with_condition(m, 1e3, 7)
    x = np.asarray(model.ns_inverse(a))
    want = np.linalg.inv(np.asarray(a))
    rel = np.abs(x - want).max() / np.abs(want).max()
    assert rel < 1e-9, f"rel {rel}"
