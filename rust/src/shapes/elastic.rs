//! Elasticity simulator.
//!
//! The paper's introduction motivates ContainerStress with exactly this
//! trade-off: *"Ideally, it would be nice to let a customer start small and
//! autonomously grow their cloud container capabilities through
//! 'elasticity' as compute dynamics dictate. However, in practice that
//! flexibility is not as smooth as cloud marketing teams might wish."*
//!
//! This module quantifies that claim: given a workload-growth trace, it
//! simulates (a) a **pre-scoped fixed shape** (what the ContainerStress
//! recommendation buys up front) against (b) a **reactive autoscaler**
//! that climbs the shape ladder when utilisation crosses a threshold —
//! paying a scale-up lag (SLA violations while saturated) and a migration
//! cost (retraining/transfer) on every step. Output: cost-over-time,
//! violation counts, and the crossover where pre-scoping wins.

use super::{catalog, Shape};

/// Workload intensity over time: per-epoch demand expressed as the
/// *fraction of a reference shape's capacity* (1 core-equivalent unit).
#[derive(Clone, Debug)]
pub struct GrowthTrace {
    /// Demand per epoch, in core-equivalents.
    pub demand: Vec<f64>,
    /// Wall-clock hours per epoch.
    pub hours_per_epoch: f64,
}

impl GrowthTrace {
    /// Exponential customer growth: `d0 · g^t` for `epochs` epochs.
    pub fn exponential(d0: f64, growth_per_epoch: f64, epochs: usize, hours: f64) -> Self {
        GrowthTrace {
            demand: (0..epochs)
                .map(|t| d0 * growth_per_epoch.powi(t as i32))
                .collect(),
            hours_per_epoch: hours,
        }
    }

    /// Step growth: demand doubles at each given epoch index.
    pub fn steps(d0: f64, step_epochs: &[usize], epochs: usize, hours: f64) -> Self {
        let mut demand = Vec::with_capacity(epochs);
        let mut d = d0;
        for t in 0..epochs {
            if step_epochs.contains(&t) {
                d *= 2.0;
            }
            demand.push(d);
        }
        GrowthTrace {
            demand,
            hours_per_epoch: hours,
        }
    }
}

/// Autoscaler policy.
#[derive(Clone, Copy, Debug)]
pub struct ElasticPolicy {
    /// Scale up when utilisation exceeds this.
    pub scale_up_at: f64,
    /// Scale down when utilisation drops below this.
    pub scale_down_at: f64,
    /// Epochs of lag before a scale-up takes effect (provisioning +
    /// retraining); demand above capacity during the lag violates SLA.
    pub scale_lag_epochs: usize,
    /// One-off cost per migration (USD — data transfer + retraining time).
    pub migration_usd: f64,
}

impl Default for ElasticPolicy {
    fn default() -> Self {
        ElasticPolicy {
            scale_up_at: 0.8,
            scale_down_at: 0.3,
            scale_lag_epochs: 2,
            migration_usd: 5.0,
        }
    }
}

/// Result of one strategy simulation.
#[derive(Clone, Debug)]
pub struct ElasticOutcome {
    /// Total spend over the trace (USD).
    pub total_usd: f64,
    /// Epochs in which demand exceeded provisioned capacity.
    pub violation_epochs: usize,
    /// Number of shape migrations performed.
    pub migrations: usize,
    /// Shape name per epoch (for reporting).
    pub shape_trace: Vec<&'static str>,
}

/// Capacity of a shape in core-equivalents (relative to a 1-core VM).
fn capacity(shape: &Shape) -> f64 {
    let base = catalog()[0].cpu_eff_flops();
    shape.cpu_eff_flops() / base
}

/// CPU-shape ladder sorted by capacity.
fn ladder() -> Vec<Shape> {
    let mut v: Vec<Shape> = catalog().into_iter().filter(|s| !s.has_gpu()).collect();
    v.sort_by(|a, b| capacity(a).partial_cmp(&capacity(b)).unwrap());
    v
}

/// Simulate a fixed, pre-scoped shape over the trace.
pub fn simulate_fixed(shape: &Shape, trace: &GrowthTrace) -> ElasticOutcome {
    let cap = capacity(shape);
    let mut violations = 0;
    for &d in &trace.demand {
        if d > cap {
            violations += 1;
        }
    }
    ElasticOutcome {
        total_usd: shape.usd_per_hour * trace.hours_per_epoch * trace.demand.len() as f64,
        violation_epochs: violations,
        migrations: 0,
        shape_trace: vec![shape.name; trace.demand.len()],
    }
}

/// Simulate the reactive autoscaler over the trace.
pub fn simulate_elastic(policy: &ElasticPolicy, trace: &GrowthTrace) -> ElasticOutcome {
    let ladder = ladder();
    let mut level = 0usize;
    let mut pending: Option<(usize, usize)> = None; // (target level, ready epoch)
    let mut total = 0.0;
    let mut violations = 0;
    let mut migrations = 0;
    let mut shape_trace = Vec::with_capacity(trace.demand.len());
    for (t, &d) in trace.demand.iter().enumerate() {
        // complete a pending migration
        if let Some((target, ready)) = pending {
            if t >= ready {
                level = target;
                migrations += 1;
                total += policy.migration_usd;
                pending = None;
            }
        }
        let shape = &ladder[level];
        let cap = capacity(shape);
        let util = d / cap;
        if util > 1.0 {
            violations += 1;
        }
        // policy decisions (only when no migration is in flight)
        if pending.is_none() {
            if util > policy.scale_up_at && level + 1 < ladder.len() {
                // pick the smallest level with headroom
                let target = (level + 1..ladder.len())
                    .find(|&l| d / capacity(&ladder[l]) <= policy.scale_up_at)
                    .unwrap_or(ladder.len() - 1);
                pending = Some((target, t + policy.scale_lag_epochs));
            } else if util < policy.scale_down_at && level > 0 {
                let target = (0..level)
                    .find(|&l| d / capacity(&ladder[l]) <= policy.scale_up_at)
                    .unwrap_or(level - 1);
                pending = Some((target, t + 1)); // scale-down is fast
            }
        }
        total += shape.usd_per_hour * trace.hours_per_epoch;
        shape_trace.push(shape.name);
    }
    ElasticOutcome {
        total_usd: total,
        violation_epochs: violations,
        migrations,
        shape_trace,
    }
}

/// Side-by-side comparison used by reports: returns (fixed, elastic) for a
/// pre-scoped shape chosen to cover the trace's *final* demand — the
/// ContainerStress recommendation.
pub fn compare(trace: &GrowthTrace, policy: &ElasticPolicy) -> (ElasticOutcome, ElasticOutcome) {
    let peak = trace.demand.iter().cloned().fold(0.0, f64::max);
    let ladder = ladder();
    let scoped = ladder
        .iter()
        .find(|s| capacity(s) >= peak / 0.8)
        .unwrap_or_else(|| ladder.last().unwrap())
        .clone();
    (
        simulate_fixed(&scoped, trace),
        simulate_elastic(policy, trace),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_shape_covering_peak_never_violates() {
        // growth kept inside the catalog's largest CPU shape (~35 core-eq)
        let trace = GrowthTrace::exponential(0.5, 1.04, 80, 24.0);
        let (fixed, _) = compare(&trace, &ElasticPolicy::default());
        assert_eq!(fixed.violation_epochs, 0);
        assert_eq!(fixed.migrations, 0);
    }

    #[test]
    fn elastic_violates_during_scale_lag() {
        // Paper's point: elasticity "is not as smooth" — a fast-growing
        // workload outruns the scale-up lag and takes SLA hits.
        let trace = GrowthTrace::steps(0.5, &[10, 20, 30], 60, 24.0);
        let elastic = simulate_elastic(&ElasticPolicy::default(), &trace);
        assert!(
            elastic.violation_epochs > 0,
            "step growth must violate during lag"
        );
        assert!(elastic.migrations >= 3);
    }

    #[test]
    fn elastic_cheaper_for_slow_growth() {
        // A workload that stays small for most of its life: paying for the
        // peak-scoped shape the whole time costs more.
        let trace = GrowthTrace::exponential(0.3, 1.02, 200, 24.0);
        let (fixed, elastic) = compare(&trace, &ElasticPolicy::default());
        assert!(
            elastic.total_usd < fixed.total_usd,
            "elastic {:.0} vs fixed {:.0}",
            elastic.total_usd,
            fixed.total_usd
        );
    }

    #[test]
    fn fixed_wins_on_violations_elastic_on_cost() {
        let trace = GrowthTrace::steps(0.4, &[5, 15, 25], 50, 24.0);
        let (fixed, elastic) = compare(&trace, &ElasticPolicy::default());
        assert_eq!(fixed.violation_epochs, 0);
        assert!(elastic.violation_epochs > 0);
        assert!(elastic.total_usd < fixed.total_usd);
    }

    #[test]
    fn scale_down_happens() {
        let mut demand = vec![8.0; 20];
        demand.extend(vec![0.5; 40]);
        let trace = GrowthTrace {
            demand,
            hours_per_epoch: 24.0,
        };
        let elastic = simulate_elastic(&ElasticPolicy::default(), &trace);
        let last = elastic.shape_trace.last().unwrap();
        let first_big = elastic.shape_trace[5];
        assert_ne!(last, &first_big, "autoscaler never scaled down");
    }

    #[test]
    fn trace_generators() {
        let e = GrowthTrace::exponential(1.0, 2.0, 4, 1.0);
        assert_eq!(e.demand, vec![1.0, 2.0, 4.0, 8.0]);
        let s = GrowthTrace::steps(1.0, &[2], 4, 1.0);
        assert_eq!(s.demand, vec![1.0, 1.0, 2.0, 2.0]);
    }
}
