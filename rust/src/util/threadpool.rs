//! Work-stealing parallel map + the shared **trial executor**.
//!
//! `tokio`/`rayon` are unavailable offline; the sweep engine is compute-bound
//! fan-out, so a scoped thread pool with an atomic work index covers the
//! one-shot case ([`parallel_map`]) and a persistent worker pool with
//! per-job queues covers the service case ([`TrialExecutor`]).
//!
//! The executor's unit of scheduling is a single submitted task (one
//! `(cell, trial)` measurement in the coordinator). Each registered job
//! owns a queue; workers pick the next task by **weighted fair queueing**
//! (stride scheduling over per-job virtual time), so a small job's tasks
//! interleave with — rather than wait behind — a giant sweep's backlog.
//! Cancellation is cooperative: cancelling a job's [`CancelToken`] makes
//! the executor drop that job's queued tasks at the next dispatch; tasks
//! already running finish normally.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Run `f(i, &items[i])` over all items on `workers` threads, returning the
/// results in input order. `f` must be `Sync` (it is shared, not cloned).
pub fn parallel_map<T, R, F>(workers: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let workers = workers.clamp(1, items.len().max(1));
    if workers == 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }
    let next = AtomicUsize::new(0);
    // Each slot is written by exactly one worker (the atomic index hands
    // out every `i` once), so plain unsynchronised writes are safe — the
    // scope join publishes them to the parent thread. A per-slot `Mutex`
    // here would be pure overhead on the hot fan-out path.
    let slots = SlotWriter::new(items.len());
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(i, &items[i]);
                // SAFETY: `i` came from `fetch_add`, so no other worker
                // ever writes this slot, and the parent only reads after
                // the scope joins every worker.
                unsafe { slots.write(i, r) };
            });
        }
    });
    slots.into_results()
}

/// Write-once result slots shared across `parallel_map` workers. Disjoint
/// indices are written without locks; `Sync` is sound because every index
/// is claimed by exactly one worker via an atomic counter.
struct SlotWriter<R> {
    slots: Vec<std::cell::UnsafeCell<Option<R>>>,
}

// SAFETY: workers only touch disjoint slots (unique `fetch_add` indices),
// and results are read only after all writers have been joined.
unsafe impl<R: Send> Sync for SlotWriter<R> {}

impl<R> SlotWriter<R> {
    fn new(n: usize) -> SlotWriter<R> {
        let mut slots = Vec::with_capacity(n);
        slots.resize_with(n, || std::cell::UnsafeCell::new(None));
        SlotWriter { slots }
    }

    /// # Safety
    /// `i` must be claimed by exactly one worker, and no reads may happen
    /// concurrently with writes (the scope join is the barrier).
    unsafe fn write(&self, i: usize, r: R) {
        *self.slots[i].get() = Some(r);
    }

    fn into_results(self) -> Vec<R> {
        self.slots
            .into_iter()
            .map(|c| c.into_inner().expect("worker missed slot"))
            .collect()
    }
}

/// Number of usable worker threads on this machine.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
}

/// Cooperative cancellation flag shared between a job's owner and the
/// executor. Cancelling is idempotent and purely advisory: queued tasks of
/// a cancelled job are dropped at the executor's next dispatch, running
/// tasks finish, and long-running owners are expected to poll
/// [`CancelToken::is_cancelled`] between units of work.
#[derive(Clone, Debug, Default)]
pub struct CancelToken(Arc<AtomicBool>);

impl CancelToken {
    /// Fresh, uncancelled token.
    pub fn new() -> CancelToken {
        CancelToken::default()
    }

    /// Request cancellation (sticky; cannot be undone).
    pub fn cancel(&self) {
        self.0.store(true, Ordering::SeqCst);
    }

    /// Whether cancellation has been requested.
    pub fn is_cancelled(&self) -> bool {
        self.0.load(Ordering::SeqCst)
    }
}

type Task = Box<dyn FnOnce() + Send + 'static>;

/// One job's task queue inside the executor.
struct JobQueue {
    id: u64,
    /// Fair-share weight (tasks dispatched per unit of virtual time).
    weight: f64,
    /// Stride-scheduling virtual time: grows by `1/weight` per dispatch;
    /// the runnable queue with the smallest value is served next.
    vtime: f64,
    tasks: VecDeque<Task>,
    cancel: CancelToken,
    /// Tasks of this job currently executing on workers.
    running: usize,
    /// The owning [`JobTicket`] was dropped — remove once drained.
    closed: bool,
}

struct ExecState {
    queues: Vec<JobQueue>,
    next_id: u64,
    shutdown: bool,
    /// Monotone virtual clock: the largest virtual start time ever
    /// dispatched. Jobs registering or re-activating are clamped to it so
    /// an all-idle window can never hand a newcomer a huge head start
    /// (vtime 0) over a job with accumulated virtual time.
    vclock: f64,
}

struct ExecShared {
    state: Mutex<ExecState>,
    work: Condvar,
    /// Also notified on task completion (owners waiting for drain).
    idle: Condvar,
    fair: bool,
    workers: usize,
}

impl ExecShared {
    /// Smallest virtual time among runnable queues (fair-share "now").
    fn min_vtime(st: &ExecState) -> Option<f64> {
        st.queues
            .iter()
            .filter(|q| !q.tasks.is_empty() || q.running > 0)
            .map(|q| q.vtime)
            .reduce(f64::min)
    }

    /// Drop queued tasks of cancelled jobs and remove dead queues. Dropped
    /// closures release whatever they captured (result senders etc.), which
    /// is how owners observe that queued work was reclaimed.
    fn sweep_dead(st: &mut ExecState) {
        for q in &mut st.queues {
            if q.cancel.is_cancelled() && !q.tasks.is_empty() {
                q.tasks.clear();
            }
        }
        st.queues.retain(|q| {
            let dead =
                q.tasks.is_empty() && q.running == 0 && (q.closed || q.cancel.is_cancelled());
            !dead
        });
    }

    /// Index of the queue to serve next, if any task is runnable.
    fn pick(&self, st: &ExecState) -> Option<usize> {
        let runnable = st.queues.iter().enumerate().filter(|(_, q)| {
            !q.tasks.is_empty() && !q.cancel.is_cancelled()
        });
        if self.fair {
            // Weighted fair queueing: smallest virtual time wins; ties go
            // to the earlier-registered job for determinism.
            runnable
                .min_by(|(_, a), (_, b)| {
                    a.vtime.total_cmp(&b.vtime).then(a.id.cmp(&b.id))
                })
                .map(|(i, _)| i)
        } else {
            // FIFO across jobs: drain in registration order (the old
            // single-leader discipline, kept as a comparison baseline).
            runnable.min_by_key(|(_, q)| q.id).map(|(i, _)| i)
        }
    }
}

/// Point-in-time view of the executor's scheduling state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecutorStats {
    /// Tasks sitting in job queues, not yet dispatched.
    pub queued: usize,
    /// Tasks currently executing on workers.
    pub running: usize,
    /// Registered job queues still alive (queued, running, or open).
    pub jobs: usize,
    /// Worker threads in the pool.
    pub workers: usize,
}

impl ExecutorStats {
    /// Fraction of workers currently executing a task, in [0, 1].
    pub fn busy_fraction(&self) -> f64 {
        if self.workers == 0 {
            0.0
        } else {
            self.running as f64 / self.workers as f64
        }
    }
}

/// Shared work-stealing executor with per-job task queues, weighted fair
/// interleaving across jobs, and cooperative cancellation.
///
/// Register a job with [`TrialExecutor::register`], submit tasks through
/// the returned [`JobTicket`], and drop the ticket when no more tasks will
/// be submitted. Dropping the executor drains every queued task first
/// (graceful shutdown), matching the old `JobPool` semantics.
pub struct TrialExecutor {
    shared: Arc<ExecShared>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl TrialExecutor {
    /// Spawn an executor with `workers` threads (min 1). `fair` selects
    /// weighted fair interleaving across jobs; `false` falls back to
    /// strict job-arrival FIFO (head-of-line blocking, kept for A/B
    /// comparisons and benchmarks).
    pub fn new(workers: usize, fair: bool) -> TrialExecutor {
        let workers = workers.max(1);
        let shared = Arc::new(ExecShared {
            state: Mutex::new(ExecState {
                queues: Vec::new(),
                next_id: 1,
                shutdown: false,
                vclock: 0.0,
            }),
            work: Condvar::new(),
            idle: Condvar::new(),
            fair,
            workers,
        });
        let handles = (0..workers)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("trial-exec-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawn executor worker")
            })
            .collect();
        TrialExecutor { shared, handles }
    }

    /// Number of worker threads.
    pub fn workers(&self) -> usize {
        self.shared.workers
    }

    /// Whether fair interleaving is enabled.
    pub fn fair(&self) -> bool {
        self.shared.fair
    }

    /// Instantaneous scheduler snapshot (drives the `executor.*` gauges
    /// served by `GET /metrics`): queue depth, busy workers, and live job
    /// queues under one lock, so the numbers are mutually consistent.
    pub fn stats(&self) -> ExecutorStats {
        let st = self.shared.state.lock().unwrap();
        let queued = st.queues.iter().map(|q| q.tasks.len()).sum();
        let running = st.queues.iter().map(|q| q.running).sum();
        ExecutorStats {
            queued,
            running,
            jobs: st.queues.len(),
            workers: self.shared.workers,
        }
    }

    /// Register a job with the given fair-share `weight` (clamped to
    /// `[1/16, 16]`; 1.0 = an equal share). Higher weights receive
    /// proportionally more task dispatches while contended.
    pub fn register(&self, weight: f64) -> JobTicket {
        let weight = if weight.is_finite() {
            weight.clamp(1.0 / 16.0, 16.0)
        } else {
            1.0
        };
        let cancel = CancelToken::new();
        let mut st = self.shared.state.lock().unwrap();
        let id = st.next_id;
        st.next_id += 1;
        // A job joining mid-flight starts at the scheduler's current
        // virtual time, so it shares fairly from now on instead of being
        // handed an unbounded catch-up burst — clamped to the monotone
        // clock so an all-idle instant doesn't reset "now" to zero.
        let vtime = ExecShared::min_vtime(&st).unwrap_or(0.0).max(st.vclock);
        st.queues.push(JobQueue {
            id,
            weight,
            vtime,
            tasks: VecDeque::new(),
            cancel: cancel.clone(),
            running: 0,
            closed: false,
        });
        JobTicket {
            id,
            shared: Arc::clone(&self.shared),
            cancel,
        }
    }

    /// Drain all queued tasks and join the workers.
    pub fn shutdown(mut self) {
        self.stop();
    }

    fn stop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
        }
        self.shared.work.notify_all();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for TrialExecutor {
    fn drop(&mut self) {
        self.stop();
    }
}

fn worker_loop(shared: &ExecShared) {
    loop {
        let (task, qid) = {
            let mut st = shared.state.lock().unwrap();
            loop {
                ExecShared::sweep_dead(&mut st);
                if let Some(i) = shared.pick(&st) {
                    let start = st.queues[i].vtime;
                    st.vclock = st.vclock.max(start);
                    let q = &mut st.queues[i];
                    let task = q.tasks.pop_front().expect("picked queue non-empty");
                    q.vtime += 1.0 / q.weight;
                    q.running += 1;
                    break (task, q.id);
                }
                if st.shutdown {
                    return;
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        // A panicking task must not kill the shared worker or strand the
        // job's `running` count — confine the panic to the task.
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(task));
        if r.is_err() {
            log::error!("trial executor: task of job {qid} panicked");
        }
        let mut st = shared.state.lock().unwrap();
        if let Some(q) = st.queues.iter_mut().find(|q| q.id == qid) {
            q.running -= 1;
        }
        ExecShared::sweep_dead(&mut st);
        drop(st);
        shared.idle.notify_all();
    }
}

/// Submission handle for one registered job. Dropping it marks the job
/// finished: remaining queued tasks still run (unless cancelled), then the
/// queue is removed.
pub struct JobTicket {
    id: u64,
    shared: Arc<ExecShared>,
    cancel: CancelToken,
}

impl JobTicket {
    /// Queue one task for this job. Tasks submitted after cancellation are
    /// dropped immediately.
    pub fn submit(&self, task: impl FnOnce() + Send + 'static) {
        if self.cancel.is_cancelled() {
            return; // dropped, like queued tasks of a cancelled job
        }
        let mut st = self.shared.state.lock().unwrap();
        if st.shutdown {
            return;
        }
        let now = ExecShared::min_vtime(&st).unwrap_or(0.0).max(st.vclock);
        if let Some(q) = st.queues.iter_mut().find(|q| q.id == self.id) {
            if q.tasks.is_empty() && q.running == 0 {
                // Re-activating an idle queue: advance to the scheduler's
                // current virtual time so banked idle credit cannot starve
                // the other jobs with a burst.
                q.vtime = q.vtime.max(now);
            }
            q.tasks.push_back(Box::new(task));
            drop(st);
            self.shared.work.notify_one();
        }
    }

    /// This job's cancellation token (share it with watchers/cancellers).
    pub fn cancel_token(&self) -> CancelToken {
        self.cancel.clone()
    }

    /// `(queued, running)` task counts for this job right now. Also
    /// reclaims cancelled queues on the spot, so a poller observing
    /// `(0, 0)` after a cancellation knows every queued task was dropped
    /// even when all workers are parked.
    pub fn pending(&self) -> (usize, usize) {
        let mut st = self.shared.state.lock().unwrap();
        ExecShared::sweep_dead(&mut st);
        st.queues
            .iter()
            .find(|q| q.id == self.id)
            .map(|q| (q.tasks.len(), q.running))
            .unwrap_or((0, 0))
    }

    /// Size of the executor this ticket belongs to (worker threads).
    pub fn executor_workers(&self) -> usize {
        self.shared.workers
    }

    /// Block until this job has no queued or running tasks (used by owners
    /// draining in-flight work after a cancellation).
    pub fn wait_idle(&self) {
        let mut st = self.shared.state.lock().unwrap();
        loop {
            ExecShared::sweep_dead(&mut st);
            let busy = st
                .queues
                .iter()
                .find(|q| q.id == self.id)
                .map(|q| !q.tasks.is_empty() || q.running > 0)
                .unwrap_or(false);
            if !busy {
                return;
            }
            st = self.shared.idle.wait(st).unwrap();
        }
    }
}

impl Drop for JobTicket {
    fn drop(&mut self) {
        let mut st = self.shared.state.lock().unwrap();
        if let Some(q) = st.queues.iter_mut().find(|q| q.id == self.id) {
            q.closed = true;
        }
        ExecShared::sweep_dead(&mut st);
        drop(st);
        // Wake workers so an all-idle pool can reap the closed queue.
        self.shared.work.notify_all();
        self.shared.idle.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = parallel_map(8, &items, |i, &x| {
            assert_eq!(i, x);
            x * 2
        });
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_map_single_worker() {
        let items = vec![1, 2, 3];
        assert_eq!(parallel_map(1, &items, |_, &x| x + 1), vec![2, 3, 4]);
    }

    #[test]
    fn parallel_map_empty() {
        let items: Vec<u8> = vec![];
        assert!(parallel_map(4, &items, |_, &x| x).is_empty());
    }

    #[test]
    fn parallel_map_actually_parallel() {
        // All workers must be in-flight at once for this to finish quickly.
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        let items: Vec<usize> = (0..8).collect();
        parallel_map(8, &items, |_, _| {
            let n = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(n, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2, "no overlap observed");
    }

    #[test]
    fn executor_roundtrip() {
        let exec = TrialExecutor::new(4, true);
        let job = exec.register(1.0);
        let (tx, rx) = mpsc::channel();
        for i in 0..100usize {
            let tx = tx.clone();
            job.submit(move || {
                let _ = tx.send(i * i);
            });
        }
        drop(tx);
        let mut got: Vec<usize> = rx.iter().collect();
        got.sort_unstable();
        assert_eq!(got, (0..100).map(|i| i * i).collect::<Vec<_>>());
        drop(job);
        exec.shutdown();
    }

    #[test]
    fn fair_interleaving_lets_small_job_finish_first() {
        // One worker, a big job queued first: with fair scheduling the
        // late-arriving small job must complete long before the big one
        // drains — the head-of-line-blocking fix this executor exists for.
        let exec = TrialExecutor::new(1, true);
        let big = exec.register(1.0);
        let (btx, brx) = mpsc::channel();
        for i in 0..50usize {
            let btx = btx.clone();
            big.submit(move || {
                std::thread::sleep(std::time::Duration::from_millis(2));
                let _ = btx.send(i);
            });
        }
        let small = exec.register(1.0);
        let (stx, srx) = mpsc::channel();
        small.submit(move || {
            let _ = stx.send(());
        });
        srx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("small job starved");
        let big_done = brx.try_iter().count();
        assert!(
            big_done < 50,
            "small job must not wait for the whole big queue"
        );
        drop((big, small));
        exec.shutdown();
    }

    #[test]
    fn weights_bias_dispatch_share() {
        // Single worker, two saturated jobs, weight 4 vs 1: by the time
        // the light job gets its 5th dispatch, the heavy job must have
        // received clearly more than an equal share.
        let exec = TrialExecutor::new(1, true);
        let heavy = exec.register(4.0);
        let light = exec.register(1.0);
        let heavy_done = Arc::new(AtomicUsize::new(0));
        let (ltx, lrx) = mpsc::channel();
        for _ in 0..200 {
            let c = Arc::clone(&heavy_done);
            heavy.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(200));
            });
        }
        for i in 0..5usize {
            let ltx = ltx.clone();
            let c = Arc::clone(&heavy_done);
            light.submit(move || {
                let _ = ltx.send((i, c.load(Ordering::SeqCst)));
                std::thread::sleep(std::time::Duration::from_micros(200));
            });
        }
        let mut heavy_at_light5 = 0;
        for _ in 0..5 {
            let (_, h) = lrx.recv_timeout(std::time::Duration::from_secs(10)).unwrap();
            heavy_at_light5 = h;
        }
        assert!(
            heavy_at_light5 >= 10,
            "weight-4 job got only {heavy_at_light5} dispatches alongside 5 weight-1 ones"
        );
        drop((heavy, light));
        exec.shutdown();
    }

    #[test]
    fn cancel_reclaims_queued_tasks() {
        let exec = TrialExecutor::new(1, true);
        let job = exec.register(1.0);
        let ran = Arc::new(AtomicUsize::new(0));
        let gate = Arc::new(std::sync::Barrier::new(2));
        {
            let gate = Arc::clone(&gate);
            job.submit(move || {
                gate.wait(); // hold the only worker until cancel lands
                std::thread::sleep(std::time::Duration::from_millis(20));
            });
        }
        for _ in 0..64 {
            let ran = Arc::clone(&ran);
            job.submit(move || {
                ran.fetch_add(1, Ordering::SeqCst);
            });
        }
        let token = job.cancel_token();
        token.cancel();
        gate.wait(); // release the in-flight task only after cancellation
        job.wait_idle();
        assert_eq!(
            ran.load(Ordering::SeqCst),
            0,
            "queued tasks of a cancelled job must be dropped, not run"
        );
        // Submissions after cancellation are also dropped.
        let ran2 = Arc::clone(&ran);
        job.submit(move || {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        job.wait_idle();
        assert_eq!(ran.load(Ordering::SeqCst), 0);
        drop(job);
        exec.shutdown();
    }

    #[test]
    fn panicking_task_does_not_kill_worker_or_strand_job() {
        let exec = TrialExecutor::new(1, true);
        let job = exec.register(1.0);
        job.submit(|| panic!("boom"));
        let (tx, rx) = mpsc::channel();
        job.submit(move || {
            let _ = tx.send(());
        });
        rx.recv_timeout(std::time::Duration::from_secs(10))
            .expect("worker must survive a panicking task");
        job.wait_idle();
        assert_eq!(job.pending(), (0, 0), "panicked task leaked a running slot");
        drop(job);
        exec.shutdown();
    }

    #[test]
    fn late_job_does_not_start_at_virtual_time_zero() {
        // Job A banks virtual time, goes idle; job B registers during the
        // all-idle window. When A resubmits, B must not get thousands of
        // dispatches of catch-up credit (the monotone vclock clamp).
        let exec = TrialExecutor::new(1, true);
        let a = exec.register(1.0);
        for _ in 0..50 {
            a.submit(|| {});
        }
        a.wait_idle(); // A idle with vtime ≈ 50; executor momentarily empty
        let b = exec.register(1.0);
        let a_done = Arc::new(AtomicUsize::new(0));
        let (btx, brx) = mpsc::channel();
        for _ in 0..50 {
            let c = Arc::clone(&a_done);
            a.submit(move || {
                c.fetch_add(1, Ordering::SeqCst);
                std::thread::sleep(std::time::Duration::from_micros(100));
            });
        }
        for i in 0..5usize {
            let btx = btx.clone();
            let c = Arc::clone(&a_done);
            b.submit(move || {
                let _ = btx.send((i, c.load(Ordering::SeqCst)));
                std::thread::sleep(std::time::Duration::from_micros(100));
            });
        }
        let mut a_at_b5 = 0;
        for _ in 0..5 {
            let (_, done) = brx
                .recv_timeout(std::time::Duration::from_secs(10))
                .unwrap();
            a_at_b5 = done;
        }
        assert!(
            a_at_b5 >= 2,
            "job A starved behind a later registrant ({a_at_b5} dispatches)"
        );
        drop((a, b));
        exec.shutdown();
    }

    #[test]
    fn shutdown_drains_queued_tasks() {
        let exec = TrialExecutor::new(2, false);
        let job = exec.register(1.0);
        let done = Arc::new(AtomicUsize::new(0));
        for _ in 0..32 {
            let done = Arc::clone(&done);
            job.submit(move || {
                done.fetch_add(1, Ordering::SeqCst);
            });
        }
        drop(job);
        exec.shutdown();
        assert_eq!(done.load(Ordering::SeqCst), 32);
    }

    #[test]
    fn stats_snapshot_tracks_queue_depth_and_busy_workers() {
        let exec = TrialExecutor::new(2, true);
        let s = exec.stats();
        assert_eq!((s.queued, s.running, s.jobs, s.workers), (0, 0, 0, 2));
        assert_eq!(s.busy_fraction(), 0.0);
        let job = exec.register(1.0);
        let gate = Arc::new(std::sync::Barrier::new(2));
        {
            let gate = Arc::clone(&gate);
            job.submit(move || {
                gate.wait();
            });
        }
        // wait (bounded) for the task to occupy a worker
        let t0 = std::time::Instant::now();
        while exec.stats().running < 1 {
            assert!(t0.elapsed().as_secs() < 10, "task never started");
            std::thread::yield_now();
        }
        let s = exec.stats();
        assert_eq!(s.running, 1);
        assert_eq!(s.jobs, 1);
        assert!((s.busy_fraction() - 0.5).abs() < 1e-12);
        gate.wait();
        job.wait_idle();
        let s = exec.stats();
        assert_eq!((s.queued, s.running), (0, 0));
        drop(job);
        exec.shutdown();
    }

    #[test]
    fn pending_and_wait_idle_track_job_state() {
        let exec = TrialExecutor::new(2, true);
        let job = exec.register(1.0);
        assert_eq!(job.pending(), (0, 0));
        let (tx, rx) = mpsc::channel();
        job.submit(move || {
            let _ = tx.send(());
        });
        rx.recv_timeout(std::time::Duration::from_secs(5)).unwrap();
        job.wait_idle();
        assert_eq!(job.pending(), (0, 0));
        assert_eq!(job.executor_workers(), 2);
        drop(job);
        exec.shutdown();
    }
}
