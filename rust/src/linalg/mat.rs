//! Dense row-major `f64` matrix used throughout the L3 analysis code
//! (TPSS shaping, response-surface fitting, the native MSET oracle).
//!
//! The type stays intentionally small; the compute-heavy products
//! ([`Mat::matmul`], [`Mat::transpose`]) delegate to the blocked
//! [`super::kernel`] core, and `_into` variants there let hot callers
//! reuse buffers through a [`super::workspace::Workspace`] instead of
//! allocating per call.

use super::kernel;
use super::workspace::Workspace;
use std::ops::{Index, IndexMut};

/// Dense row-major matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    /// Row count.
    pub rows: usize,
    /// Column count.
    pub cols: usize,
    /// Elements, row-major (`rows × cols`).
    pub data: Vec<f64>,
}

impl Mat {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    /// Identity matrix.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Matrix from equal-length row vectors.
    pub fn from_rows(rows: Vec<Vec<f64>>) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        Mat {
            rows: r,
            cols: c,
            data: rows.into_iter().flatten().collect(),
        }
    }

    /// Matrix wrapping an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat { rows, cols, data }
    }

    /// Row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row `r`.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Column `c`, top to bottom, as an iterator — no per-column
    /// allocation. Use [`Mat::col_into`] when a contiguous slice is
    /// needed, or `.collect::<Vec<_>>()` for a one-off copy.
    pub fn col(&self, c: usize) -> impl Iterator<Item = f64> + '_ {
        assert!(c < self.cols || self.rows == 0, "column {c} out of bounds");
        self.data
            .get(c..)
            .unwrap_or(&[])
            .iter()
            .step_by(self.cols.max(1))
            .copied()
    }

    /// Write column `c` into a caller-owned buffer (cleared first), so
    /// repeated extraction reuses one allocation.
    pub fn col_into(&self, c: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend(self.col(c));
    }

    /// Re-shape in place to `rows × cols`, resizing the backing buffer.
    /// Existing elements are **not** rearranged — this is for `_into`
    /// output parameters whose every element is about to be overwritten.
    pub fn reshape(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.resize(rows * cols, 0.0);
    }

    /// Transposed copy (blocked; see [`Mat::transpose_into`]).
    pub fn transpose(&self) -> Mat {
        let mut t = Mat::zeros(0, 0);
        self.transpose_into(&mut t);
        t
    }

    /// Blocked transpose into a caller-owned matrix.
    pub fn transpose_into(&self, out: &mut Mat) {
        out.reshape(self.cols, self.rows);
        kernel::pack_transpose(&mut out.data, &self.data, self.rows, self.cols);
    }

    /// `self * other` through the blocked [`super::kernel`] core (packed
    /// Bᵀ panels, 4×4 register tiles). Per-element accumulation order is
    /// the plain ascending-`k` dot product, so results match the naive
    /// triple loop bit for bit. Hot callers should prefer
    /// [`kernel::matmul_into`] with an explicit workspace.
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        Workspace::with(|ws| {
            let mut out = Mat::zeros(0, 0);
            kernel::matmul_into(&mut out, self, other, ws);
            out
        })
    }

    /// Matrix–vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Scalar multiple.
    pub fn scale(&self, s: f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// Element-wise sum.
    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a + b)
                .collect(),
        }
    }

    /// Element-wise difference.
    pub fn sub(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(a, b)| a - b)
                .collect(),
        }
    }

    /// Frobenius norm.
    pub fn norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Mat) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }

    /// Convert to `f32` (row-major) for PJRT literal marshaling.
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }

    /// Build from an `f32` buffer (device output).
    pub fn from_f32(rows: usize, cols: usize, data: &[f32]) -> Mat {
        assert_eq!(data.len(), rows * cols);
        Mat {
            rows,
            cols,
            data: data.iter().map(|&x| x as f64).collect(),
        }
    }
}

impl Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0]]);
        let b = Mat::from_rows(vec![vec![5.0, 6.0], vec![7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(vec![vec![19.0, 22.0], vec![43.0, 50.0]]));
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.matmul(&Mat::eye(3)), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0, 3.0], vec![4.0, 5.0, 6.0]]);
        assert_eq!(a.transpose().transpose(), a);
        assert_eq!(a.transpose().rows, 3);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_rows(vec![vec![1.0, -1.0], vec![2.0, 0.5]]);
        let v = vec![3.0, 4.0];
        assert_eq!(a.matvec(&v), vec![-1.0, 8.0]);
    }

    #[test]
    fn f32_roundtrip() {
        let a = Mat::from_rows(vec![vec![1.5, 2.5]]);
        let b = Mat::from_f32(1, 2, &a.to_f32());
        assert_eq!(a, b);
    }

    #[test]
    #[should_panic]
    fn matmul_dim_mismatch_panics() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        a.matmul(&b);
    }

    #[test]
    fn col_iterates_and_copies() {
        let a = Mat::from_rows(vec![vec![1.0, 2.0], vec![3.0, 4.0], vec![5.0, 6.0]]);
        assert_eq!(a.col(1).collect::<Vec<_>>(), vec![2.0, 4.0, 6.0]);
        let mut buf = vec![9.0; 10];
        a.col_into(0, &mut buf);
        assert_eq!(buf, vec![1.0, 3.0, 5.0]);
        // empty matrix: no panic, no elements
        assert_eq!(Mat::zeros(0, 0).col(0).count(), 0);
    }

    #[test]
    fn reshape_resizes_buffer() {
        let mut a = Mat::zeros(2, 2);
        a.reshape(3, 4);
        assert_eq!((a.rows, a.cols, a.data.len()), (3, 4, 12));
        a.reshape(1, 2);
        assert_eq!((a.rows, a.cols, a.data.len()), (1, 2, 2));
    }
}
