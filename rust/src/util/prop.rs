//! Randomised property-testing harness (offline substitute for `proptest`).
//!
//! Usage:
//!
//! ```ignore
//! use containerstress::util::prop::forall;
//! forall("router picks smallest bucket", 200, |rng| gen_workload(rng), |w| {
//!     check(w)
//! });
//! ```
//!
//! On failure the harness panics with the case index, seed and a debug dump
//! of the failing input, so the case can be replayed deterministically with
//! [`replay`]. (No shrinking — generators are encouraged to produce small
//! cases with reasonable probability instead.)

use super::rng::Rng;

/// Base seed; override with `CONTAINERSTRESS_PROP_SEED` to replay a run.
fn base_seed() -> u64 {
    std::env::var("CONTAINERSTRESS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Check `prop(gen(rng))` for `cases` generated inputs.
pub fn forall<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n{input:#?}\n\
                 replay with CONTAINERSTRESS_PROP_SEED={seed}"
            );
        }
    }
}

/// Like [`forall`] but the property returns `Result` so failures can carry
/// a message.
pub fn forall_res<T, G, P>(name: &str, cases: usize, mut gen: G, mut prop: P)
where
    T: std::fmt::Debug,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> Result<(), String>,
{
    let seed = base_seed();
    for case in 0..cases {
        let mut rng = Rng::new(seed).fork(case as u64);
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}): {msg}\n{input:#?}\n\
                 replay with CONTAINERSTRESS_PROP_SEED={seed}"
            );
        }
    }
}

/// Replay a single failing case by index.
pub fn replay<T, G>(case: usize, mut gen: G) -> T
where
    G: FnMut(&mut Rng) -> T,
{
    let mut rng = Rng::new(base_seed()).fork(case as u64);
    gen(&mut rng)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property() {
        forall("reverse twice is identity", 100, |rng| {
            let n = rng.range_usize(0, 20);
            (0..n).map(|_| rng.below(100)).collect::<Vec<_>>()
        }, |v| {
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            w == *v
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_panics_with_case() {
        forall("always fails", 10, |rng| rng.below(10), |_| false);
    }

    #[test]
    fn replay_matches_forall_generation() {
        let from_replay: Vec<u64> = (0..5)
            .map(|c| replay(c, |rng: &mut Rng| rng.below(1000)))
            .collect();
        let mut from_forall = Vec::new();
        forall("collect", 5, |rng| rng.below(1000), |x| {
            from_forall.push(*x);
            true
        });
        assert_eq!(from_replay, from_forall);
    }
}
